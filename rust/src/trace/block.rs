//! Chunked SoA event blocks — the batched form of a trace.
//!
//! The original replay path pushes every instruction through one
//! `&mut dyn EventSink` virtual call, which caps throughput at
//! per-event dispatch cost. An [`EventBlock`] instead packs a window of
//! the stream into parallel arrays (structure-of-arrays):
//!
//! * one **record tape** — `tags` + `group_ids`, one entry per event in
//!   issue order;
//! * an **instruction stream** — `(class, count)` pairs consumed in tape
//!   order by `Tag::Inst` records;
//! * an **access stream** shared by global-memory and LDS records —
//!   `(kind, bytes_per_lane, addr offset)`, with the active lanes'
//!   byte addresses compacted into one flat `addrs` arena.
//!
//! Compaction keeps only active-lane addresses (in lane order), which
//! preserves exactly what every consumer observes: the multiset of
//! active addresses and the active-lane count. Replaying a block
//! therefore produces bit-identical statistics to the original
//! event-at-a-time stream.
//!
//! [`BlockBuilder`] adapts the existing [`EventSink`] world to blocks
//! (any `TraceSource` can fill blocks unchanged), and
//! [`EventBlock::replay_into`] adapts blocks back onto any legacy sink —
//! the compatibility bridge in the other direction.
//!
//! [`BlockData`] abstracts *where* a block's columns live: the owned
//! [`EventBlock`] and the archive's memory-mapped
//! [`crate::trace::archive::MappedBlock`] expose the same record-level
//! view, so every replay engine (and [`split half-group
//! derivation`](crate::trace::recorded::split_half_groups)) runs
//! unchanged — and zero-copy — over either storage. Since archive
//! format v2 a mapped block's columns may individually live in the
//! mapped file (raw sections) or in the archive's pooled decode arena
//! (delta-varint/RLE-compressed sections, decoded once at open); both
//! resolve through the same hoisted [`Columns`] view, exactly once
//! per block, so the hot loops cannot tell the storage forms apart.
//! The out-of-core streaming tier
//! ([`crate::trace::archive::StreamingCaseTrace`]) adds a fourth
//! backing: blocks whose columns live in a pooled per-dispatch decode
//! arena that exists only while that dispatch replays — same nine
//! slices, same `Columns` view, so the engines stay oblivious to
//! residency as well.

use super::event::{GroupCtx, LdsAccess, MemAccess, MemKind};
use super::sink::EventSink;
use crate::arch::InstClass;

/// Records per block before [`BlockBuilder`] hands the block off. Sized
/// so a block's tape and payload stay cache-friendly (~a few hundred KB
/// with full 64-lane gathers) while still amortizing per-block overhead
/// over thousands of events.
pub const BLOCK_CAPACITY: usize = 4096;

/// What one tape entry is.
///
/// `repr(u8)` with explicit discriminants equal to the archive wire
/// encoding ([`crate::trace::archive::format::tag_to_u8`]): a mapped
/// tag column whose bytes were code-validated at open is directly a
/// `&[Tag]`, which is what lets [`BlockData::columns`] hand out one
/// typed slice for either storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Tag {
    /// Non-memory instructions, batched by count.
    Inst = 0,
    /// One global-memory instruction.
    Mem = 1,
    /// One LDS / shared-memory instruction.
    Lds = 2,
}

/// A borrowed view of one record on the tape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BlockRecord<'a> {
    Inst {
        group_id: u64,
        class: InstClass,
        count: u64,
    },
    Mem {
        group_id: u64,
        kind: MemKind,
        bytes_per_lane: u8,
        /// Active-lane byte addresses, compacted in lane order.
        addrs: &'a [u64],
    },
    Lds {
        group_id: u64,
        kind: MemKind,
        bytes_per_lane: u8,
        addrs: &'a [u64],
    },
}

impl BlockRecord<'_> {
    /// The issuing group, whatever the record kind.
    pub fn group_id(&self) -> u64 {
        match *self {
            BlockRecord::Inst { group_id, .. }
            | BlockRecord::Mem { group_id, .. }
            | BlockRecord::Lds { group_id, .. } => group_id,
        }
    }
}

/// One chunk of a trace in SoA form. Reusable: [`EventBlock::clear`]
/// keeps every allocation.
#[derive(Debug, Default, Clone)]
pub struct EventBlock {
    tags: Vec<Tag>,
    group_ids: Vec<u64>,
    // instruction stream (consumed in tape order)
    inst_class: Vec<InstClass>,
    inst_count: Vec<u64>,
    // access stream, shared by Mem and Lds records
    acc_kind: Vec<MemKind>,
    acc_bpl: Vec<u8>,
    acc_off: Vec<u32>,
    acc_len: Vec<u8>,
    addrs: Vec<u64>,
}

impl EventBlock {
    pub fn with_capacity(records: usize) -> EventBlock {
        EventBlock {
            tags: Vec::with_capacity(records),
            group_ids: Vec::with_capacity(records),
            inst_class: Vec::with_capacity(records),
            inst_count: Vec::with_capacity(records),
            acc_kind: Vec::with_capacity(records),
            acc_bpl: Vec::with_capacity(records),
            acc_off: Vec::with_capacity(records),
            acc_len: Vec::with_capacity(records),
            addrs: Vec::with_capacity(records * 8),
        }
    }

    /// Number of records on the tape.
    pub fn len(&self) -> usize {
        self.tags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tags.is_empty()
    }

    /// Total address words stored (sizing aid for batch thresholds).
    pub fn addr_words(&self) -> usize {
        self.addrs.len()
    }

    /// Overwrite with `src`'s records, reusing this block's
    /// allocations (the pooled-copy path of batching consumers).
    pub fn copy_from(&mut self, src: &EventBlock) {
        self.clear();
        self.tags.extend_from_slice(&src.tags);
        self.group_ids.extend_from_slice(&src.group_ids);
        self.inst_class.extend_from_slice(&src.inst_class);
        self.inst_count.extend_from_slice(&src.inst_count);
        self.acc_kind.extend_from_slice(&src.acc_kind);
        self.acc_bpl.extend_from_slice(&src.acc_bpl);
        self.acc_off.extend_from_slice(&src.acc_off);
        self.acc_len.extend_from_slice(&src.acc_len);
        self.addrs.extend_from_slice(&src.addrs);
    }

    /// Drop all records, keeping the allocations for reuse.
    pub fn clear(&mut self) {
        self.tags.clear();
        self.group_ids.clear();
        self.inst_class.clear();
        self.inst_count.clear();
        self.acc_kind.clear();
        self.acc_bpl.clear();
        self.acc_off.clear();
        self.acc_len.clear();
        self.addrs.clear();
    }

    pub fn push_inst(&mut self, ctx: &GroupCtx, class: InstClass, count: u64) {
        self.tags.push(Tag::Inst);
        self.group_ids.push(ctx.group_id);
        self.inst_class.push(class);
        self.inst_count.push(count);
    }

    fn push_access(
        &mut self,
        tag: Tag,
        group_id: u64,
        kind: MemKind,
        bytes_per_lane: u8,
        active: u64,
        lane_addrs: &[u64; super::event::MAX_LANES],
    ) {
        self.tags.push(tag);
        self.group_ids.push(group_id);
        self.acc_kind.push(kind);
        self.acc_bpl.push(bytes_per_lane);
        self.acc_off.push(self.addrs.len() as u32);
        let mut n = 0u8;
        let mut mask = active;
        while mask != 0 {
            let lane = mask.trailing_zeros() as usize;
            self.addrs.push(lane_addrs[lane]);
            n += 1;
            mask &= mask - 1;
        }
        self.acc_len.push(n);
    }

    pub fn push_mem(&mut self, ctx: &GroupCtx, access: &MemAccess) {
        self.push_access(
            Tag::Mem,
            ctx.group_id,
            access.kind,
            access.bytes_per_lane,
            access.active,
            &access.addrs,
        );
    }

    pub fn push_lds(&mut self, ctx: &GroupCtx, access: &LdsAccess) {
        self.push_access(
            Tag::Lds,
            ctx.group_id,
            access.kind,
            access.bytes_per_lane,
            access.active,
            &access.addrs,
        );
    }

    /// Iterate the records in issue order.
    pub fn records(&self) -> BlockIter<'_> {
        BlockData::records(self)
    }

    /// Compatibility adapter: replay this block into a classic
    /// [`EventSink`], reproducing the original event stream (with
    /// active-lane compaction, which no sink can distinguish).
    pub fn replay_into(&self, sink: &mut dyn EventSink) {
        BlockData::replay_into(self, sink)
    }
}

/// Borrowed view of one block's nine SoA columns as plain slices, in
/// the on-disk section order of the trace archive (see
/// `docs/trace-format.md`): tags, group_ids, inst_class, inst_count,
/// acc_kind, acc_bpl, acc_off, acc_len, addrs.
///
/// This is the **hoisted** view the hot loops scan: derived once per
/// block via [`BlockData::columns`], then indexed as raw slices. For
/// [`crate::trace::archive::MappedBlock`] the old per-record accessors
/// re-derived this view (an `Arc` deref plus a storage-enum match) for
/// every record of every scan; hoisting it restores plain-slice
/// scanning cost for mapped storage.
#[derive(Clone, Copy)]
pub struct Columns<'a> {
    pub tags: &'a [Tag],
    pub group_ids: &'a [u64],
    pub inst_class: &'a [InstClass],
    pub inst_count: &'a [u64],
    pub acc_kind: &'a [MemKind],
    pub acc_bpl: &'a [u8],
    pub acc_off: &'a [u32],
    pub acc_len: &'a [u8],
    pub addrs: &'a [u64],
}

impl<'a> Columns<'a> {
    /// Number of records on the tape.
    pub fn len(&self) -> usize {
        self.tags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tags.is_empty()
    }

    /// Access-stream entry `i` (the i-th `Tag::Mem`/`Tag::Lds` record
    /// on the tape): `(kind, bytes_per_lane, active-lane addresses)`.
    #[inline]
    pub fn access(&self, i: usize) -> (MemKind, u8, &'a [u64]) {
        let off = self.acc_off[i] as usize;
        let len = self.acc_len[i] as usize;
        let addrs: &'a [u64] = &self.addrs[off..off + len];
        (self.acc_kind[i], self.acc_bpl[i], addrs)
    }

    /// Iterate the records in issue order.
    pub fn records(self) -> BlockIter<'a> {
        BlockIter {
            cols: self,
            tape: 0,
            inst: 0,
            acc: 0,
        }
    }
}

/// Storage-independent read access to one SoA block.
///
/// Implemented by the owned [`EventBlock`] and by the trace archive's
/// memory-mapped [`crate::trace::archive::MappedBlock`]; the replay
/// engines ([`crate::memsim::ShardedHierarchy`], the sequential
/// session path) are generic over this trait, so a recording replays
/// identically whether its columns live on the heap or in a mapped
/// file.
///
/// The trait's one real method is [`BlockData::columns`]: a borrowed
/// view of all nine columns, hoisted **once** per block. Every scan —
/// record iteration, the sharded engine's routing and L1 phases, the
/// stats fold, the half-group split — runs over those plain slices
/// instead of paying a per-record storage-resolution cost.
pub trait BlockData {
    /// Number of records on the tape.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total address words stored (sizing aid for batch thresholds).
    fn addr_words(&self) -> usize;

    /// The hoisted column view (see [`Columns`]). Implementations
    /// resolve their storage exactly once here.
    fn columns(&self) -> Columns<'_>;

    /// Iterate the records in issue order (over a hoisted column view).
    fn records(&self) -> BlockIter<'_> {
        self.columns().records()
    }

    /// Compatibility adapter: replay this block into a classic
    /// [`EventSink`], reproducing the original event stream (with
    /// active-lane compaction, which no sink can distinguish).
    fn replay_into(&self, sink: &mut dyn EventSink) {
        for rec in self.records() {
            match rec {
                BlockRecord::Inst {
                    group_id,
                    class,
                    count,
                } => sink.on_inst(&GroupCtx { group_id }, class, count),
                BlockRecord::Mem {
                    group_id,
                    kind,
                    bytes_per_lane,
                    addrs,
                } => {
                    let a = MemAccess::gather(kind, addrs, bytes_per_lane);
                    sink.on_mem(&GroupCtx { group_id }, &a);
                }
                BlockRecord::Lds {
                    group_id,
                    kind,
                    bytes_per_lane,
                    addrs,
                } => {
                    let a = LdsAccess::from_lane_addrs(
                        kind,
                        addrs,
                        bytes_per_lane,
                    );
                    sink.on_lds(&GroupCtx { group_id }, &a);
                }
            }
        }
    }
}

impl BlockData for EventBlock {
    fn len(&self) -> usize {
        self.tags.len()
    }

    fn addr_words(&self) -> usize {
        self.addrs.len()
    }

    fn columns(&self) -> Columns<'_> {
        Columns {
            tags: &self.tags,
            group_ids: &self.group_ids,
            inst_class: &self.inst_class,
            inst_count: &self.inst_count,
            acc_kind: &self.acc_kind,
            acc_bpl: &self.acc_bpl,
            acc_off: &self.acc_off,
            acc_len: &self.acc_len,
            addrs: &self.addrs,
        }
    }
}

/// Iterator over [`BlockRecord`]s: three cursors into one hoisted
/// [`Columns`] view, so iteration indexes plain slices regardless of
/// where the block's storage lives.
pub struct BlockIter<'a> {
    cols: Columns<'a>,
    tape: usize,
    inst: usize,
    acc: usize,
}

impl<'a> Iterator for BlockIter<'a> {
    type Item = BlockRecord<'a>;

    fn next(&mut self) -> Option<BlockRecord<'a>> {
        let c = &self.cols;
        if self.tape >= c.tags.len() {
            return None;
        }
        let tag = c.tags[self.tape];
        let group_id = c.group_ids[self.tape];
        self.tape += 1;
        Some(match tag {
            Tag::Inst => {
                let i = self.inst;
                self.inst += 1;
                BlockRecord::Inst {
                    group_id,
                    class: c.inst_class[i],
                    count: c.inst_count[i],
                }
            }
            Tag::Mem | Tag::Lds => {
                let i = self.acc;
                self.acc += 1;
                let (kind, bytes_per_lane, addrs) = c.access(i);
                if tag == Tag::Mem {
                    BlockRecord::Mem {
                        group_id,
                        kind,
                        bytes_per_lane,
                        addrs,
                    }
                } else {
                    BlockRecord::Lds {
                        group_id,
                        kind,
                        bytes_per_lane,
                        addrs,
                    }
                }
            }
        })
    }
}

/// Consumer of full blocks (the batched analog of [`EventSink`]).
pub trait BlockSink {
    fn on_block(&mut self, block: &EventBlock);
}

/// Any classic sink is also a block sink, via record replay.
impl<S: EventSink + ?Sized> BlockSink for S {
    fn on_block(&mut self, block: &EventBlock) {
        block.replay_into(self);
    }
}

/// Adapts the event-at-a-time world to blocks: implements [`EventSink`],
/// buffers into an [`EventBlock`], and hands full blocks to a
/// [`BlockSink`]. Call [`BlockBuilder::flush`] (or drop via
/// [`BlockBuilder::finish`]) after the trace to push the tail block.
pub struct BlockBuilder<'a, S: BlockSink + ?Sized> {
    block: EventBlock,
    sink: &'a mut S,
}

impl<'a, S: BlockSink + ?Sized> BlockBuilder<'a, S> {
    pub fn new(sink: &'a mut S) -> Self {
        BlockBuilder {
            block: EventBlock::with_capacity(BLOCK_CAPACITY),
            sink,
        }
    }

    fn maybe_flush(&mut self) {
        if self.block.len() >= BLOCK_CAPACITY {
            self.flush();
        }
    }

    /// Push the buffered partial block to the sink.
    pub fn flush(&mut self) {
        if !self.block.is_empty() {
            self.sink.on_block(&self.block);
            self.block.clear();
        }
    }

    /// Flush and release the sink borrow. (Dropping the builder also
    /// flushes; this form just makes the hand-off explicit.)
    pub fn finish(self) {}
}

/// The tail block is delivered even if the caller forgets
/// [`BlockBuilder::finish`] — silently dropping buffered events would
/// undercount every counter downstream.
impl<S: BlockSink + ?Sized> Drop for BlockBuilder<'_, S> {
    fn drop(&mut self) {
        self.flush();
    }
}

/// A [`BlockSink`] that keeps owned copies of every block — the
/// record-once/replay-many handle (see
/// [`crate::profiler::ProfileSession::profile_blocks`]).
#[derive(Debug, Default)]
pub struct BlockRecorder {
    pub blocks: Vec<EventBlock>,
}

impl BlockRecorder {
    /// Record a full trace replay as owned blocks.
    pub fn record(
        trace: &dyn crate::trace::TraceSource,
        group_size: u32,
    ) -> BlockRecorder {
        let mut rec = BlockRecorder::default();
        {
            let mut builder = BlockBuilder::new(&mut rec);
            trace.replay(group_size, &mut builder);
        }
        rec
    }
}

impl BlockSink for BlockRecorder {
    fn on_block(&mut self, block: &EventBlock) {
        let mut own = EventBlock::default();
        own.copy_from(block);
        self.blocks.push(own);
    }
}

impl<S: BlockSink + ?Sized> EventSink for BlockBuilder<'_, S> {
    fn on_inst(&mut self, ctx: &GroupCtx, class: InstClass, count: u64) {
        self.block.push_inst(ctx, class, count);
        self.maybe_flush();
    }

    fn on_mem(&mut self, ctx: &GroupCtx, access: &MemAccess) {
        self.block.push_mem(ctx, access);
        self.maybe_flush();
    }

    fn on_lds(&mut self, ctx: &GroupCtx, access: &LdsAccess) {
        self.block.push_lds(ctx, access);
        self.maybe_flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::stats::TraceStats;
    use crate::trace::synth::StreamTrace;
    use crate::trace::TraceSource;

    #[test]
    fn roundtrip_preserves_records() {
        let mut b = EventBlock::default();
        let ctx = GroupCtx { group_id: 3 };
        b.push_inst(&ctx, InstClass::ValuArith, 10);
        b.push_mem(&ctx, &MemAccess::contiguous(MemKind::Read, 64, 4, 4));
        b.push_lds(
            &ctx,
            &LdsAccess::from_lane_addrs(MemKind::Write, &[0, 4], 4),
        );
        let recs: Vec<BlockRecord> = b.records().collect();
        assert_eq!(recs.len(), 3);
        assert_eq!(
            recs[0],
            BlockRecord::Inst {
                group_id: 3,
                class: InstClass::ValuArith,
                count: 10
            }
        );
        match recs[1] {
            BlockRecord::Mem { addrs, kind, .. } => {
                assert_eq!(kind, MemKind::Read);
                assert_eq!(addrs, &[64, 68, 72, 76]);
            }
            _ => panic!("expected mem"),
        }
        match recs[2] {
            BlockRecord::Lds { addrs, .. } => assert_eq!(addrs, &[0, 4]),
            _ => panic!("expected lds"),
        }
    }

    #[test]
    fn sparse_active_mask_compacts() {
        let mut a = MemAccess::contiguous(MemKind::Read, 0, 8, 4);
        a.active = 0b1010_1010; // lanes 1,3,5,7
        let mut b = EventBlock::default();
        b.push_mem(&GroupCtx { group_id: 0 }, &a);
        match b.records().next().unwrap() {
            BlockRecord::Mem { addrs, .. } => {
                assert_eq!(addrs, &[4, 12, 20, 28]);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn builder_flushes_at_capacity_and_tail() {
        struct CountBlocks {
            blocks: usize,
            records: usize,
        }
        impl BlockSink for CountBlocks {
            fn on_block(&mut self, block: &EventBlock) {
                self.blocks += 1;
                self.records += block.len();
                assert!(block.len() <= BLOCK_CAPACITY);
            }
        }
        let mut out = CountBlocks {
            blocks: 0,
            records: 0,
        };
        {
            let mut builder = BlockBuilder::new(&mut out);
            let ctx = GroupCtx { group_id: 0 };
            for _ in 0..BLOCK_CAPACITY + 10 {
                builder.on_inst(&ctx, InstClass::Salu, 1);
            }
            builder.finish();
        }
        assert_eq!(out.blocks, 2);
        assert_eq!(out.records, BLOCK_CAPACITY + 10);
    }

    #[test]
    fn blocked_replay_matches_direct_replay() {
        let t = StreamTrace::babelstream("triad", 1 << 12);
        let mut direct = TraceStats::default();
        t.replay(64, &mut direct);

        // route the same trace through blocks into another TraceStats
        // (any EventSink is a BlockSink via the blanket impl)
        let mut blocked = TraceStats::default();
        {
            let mut builder =
                BlockBuilder::new(&mut blocked as &mut dyn EventSink);
            t.replay(64, &mut builder);
            builder.finish();
        }
        assert_eq!(direct, blocked);
    }

    #[test]
    fn clear_keeps_capacity() {
        let mut b = EventBlock::with_capacity(16);
        let ctx = GroupCtx { group_id: 0 };
        b.push_mem(&ctx, &MemAccess::contiguous(MemKind::Read, 0, 64, 4));
        let cap = b.addrs.capacity();
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.addr_words(), 0);
        assert_eq!(b.addrs.capacity(), cap);
    }
}
