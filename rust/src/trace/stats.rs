//! Aggregate statistics over a trace — the raw material for both vendors'
//! counter engines.

use super::event::{GroupCtx, LdsAccess, MemAccess, MemKind};
use super::sink::EventSink;
use crate::arch::InstClass;

/// Per-class instruction issue counts plus memory request totals,
/// all at group (warp/wavefront) granularity.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceStats {
    /// Issued group-level instructions per class (memory instructions are
    /// counted under their own classes).
    pub inst: ClassCounts,
    /// Group-level memory read instructions.
    pub mem_reads: u64,
    /// Group-level memory write instructions.
    pub mem_writes: u64,
    /// Group-level atomics.
    pub mem_atomics: u64,
    /// Total bytes requested by active lanes (reads).
    pub bytes_read_requested: u64,
    /// Total bytes requested by active lanes (writes + atomics).
    pub bytes_written_requested: u64,
    /// LDS instructions.
    pub lds_ops: u64,
    /// Total active lanes across all instructions (for divergence stats).
    pub active_lane_sum: u64,
    /// Highest group id seen + 1 (= number of groups launched).
    pub groups: u64,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClassCounts {
    counts: [u64; InstClass::ALL.len()],
}

impl ClassCounts {
    fn idx(class: InstClass) -> usize {
        InstClass::ALL.iter().position(|c| *c == class).unwrap()
    }

    pub fn add(&mut self, class: InstClass, n: u64) {
        self.counts[Self::idx(class)] += n;
    }

    pub fn get(&self, class: InstClass) -> u64 {
        self.counts[Self::idx(class)]
    }

    /// Sum over all classes — nvprof's `inst_executed` semantics.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// rocProf compute-only semantics: VALU instructions.
    pub fn valu(&self) -> u64 {
        InstClass::ALL
            .iter()
            .filter(|c| c.is_valu())
            .map(|c| self.get(*c))
            .sum()
    }

    /// rocProf compute-only semantics: SALU instructions.
    pub fn salu(&self) -> u64 {
        InstClass::ALL
            .iter()
            .filter(|c| c.is_salu())
            .map(|c| self.get(*c))
            .sum()
    }
}

impl TraceStats {
    pub fn merge(&mut self, other: &TraceStats) {
        for (a, b) in self
            .inst
            .counts
            .iter_mut()
            .zip(other.inst.counts.iter())
        {
            *a += b;
        }
        self.mem_reads += other.mem_reads;
        self.mem_writes += other.mem_writes;
        self.mem_atomics += other.mem_atomics;
        self.bytes_read_requested += other.bytes_read_requested;
        self.bytes_written_requested += other.bytes_written_requested;
        self.lds_ops += other.lds_ops;
        self.active_lane_sum += other.active_lane_sum;
        self.groups = self.groups.max(other.groups);
    }

    /// Total group-level instructions of every kind (incl. memory + LDS).
    pub fn total_group_insts(&self) -> u64 {
        self.inst.total()
    }

    /// [`TraceStats::on_record`] with an ISA-expansion factor applied
    /// to instruction counts (identity at 1.0) — the fold used when an
    /// expansion-neutral *recorded* trace is replayed for a specific
    /// GPU. Must agree with [`crate::trace::sink::ScaleInstSink`].
    pub fn on_record_scaled(
        &mut self,
        rec: &crate::trace::block::BlockRecord<'_>,
        expansion: f64,
    ) {
        use crate::trace::block::BlockRecord;
        match *rec {
            BlockRecord::Inst {
                group_id,
                class,
                count,
            } => {
                self.inst
                    .add(class, class.expand_count(count, expansion));
                self.groups = self.groups.max(group_id + 1);
            }
            _ => self.on_record(rec),
        }
    }

    /// Columnar fold of one whole block: equivalent to calling
    /// [`TraceStats::on_record_scaled`] on every record in tape order,
    /// but scanning the hoisted column slices directly — no
    /// [`crate::trace::block::BlockRecord`] is materialized and (for
    /// mapped archives) no per-record storage resolution is paid. The
    /// address payload is never touched: the compacted lane count and
    /// bytes-per-lane columns carry everything the stats need.
    pub fn fold_columns_scaled(
        &mut self,
        c: &crate::trace::block::Columns<'_>,
        expansion: f64,
    ) {
        use crate::trace::block::Tag;
        let (mut inst_i, mut acc_i) = (0usize, 0usize);
        for t in 0..c.tags.len() {
            let group_id = c.group_ids[t];
            match c.tags[t] {
                Tag::Inst => {
                    let class = c.inst_class[inst_i];
                    let count = c.inst_count[inst_i];
                    inst_i += 1;
                    self.inst.add(
                        class,
                        class.expand_count(count, expansion),
                    );
                }
                Tag::Mem => {
                    let kind = c.acc_kind[acc_i];
                    let lanes = c.acc_len[acc_i] as u64;
                    let bytes = lanes * c.acc_bpl[acc_i] as u64;
                    acc_i += 1;
                    let class = match kind {
                        MemKind::Read => InstClass::GlobalLoad,
                        MemKind::Write => InstClass::GlobalStore,
                        MemKind::Atomic => InstClass::GlobalAtomic,
                    };
                    self.inst.add(class, 1);
                    self.active_lane_sum += lanes;
                    match kind {
                        MemKind::Read => {
                            self.mem_reads += 1;
                            self.bytes_read_requested += bytes;
                        }
                        MemKind::Write => {
                            self.mem_writes += 1;
                            self.bytes_written_requested += bytes;
                        }
                        MemKind::Atomic => {
                            self.mem_atomics += 1;
                            self.bytes_read_requested += bytes;
                            self.bytes_written_requested += bytes;
                        }
                    }
                }
                Tag::Lds => {
                    let kind = c.acc_kind[acc_i];
                    acc_i += 1;
                    let class = match kind {
                        MemKind::Read => InstClass::LdsLoad,
                        _ => InstClass::LdsStore,
                    };
                    self.inst.add(class, 1);
                    self.lds_ops += 1;
                }
            }
            self.groups = self.groups.max(group_id + 1);
        }
    }

    /// Fold one batched record in — the SoA fast path, equivalent to the
    /// [`EventSink`] methods but without rebuilding a 512-byte access
    /// struct per record.
    pub fn on_record(&mut self, rec: &crate::trace::block::BlockRecord<'_>) {
        use crate::trace::block::BlockRecord;
        match *rec {
            BlockRecord::Inst {
                group_id,
                class,
                count,
            } => {
                self.inst.add(class, count);
                self.groups = self.groups.max(group_id + 1);
            }
            BlockRecord::Mem {
                group_id,
                kind,
                bytes_per_lane,
                addrs,
            } => {
                let class = match kind {
                    MemKind::Read => InstClass::GlobalLoad,
                    MemKind::Write => InstClass::GlobalStore,
                    MemKind::Atomic => InstClass::GlobalAtomic,
                };
                self.inst.add(class, 1);
                let lanes = addrs.len() as u64;
                self.active_lane_sum += lanes;
                let bytes = lanes * bytes_per_lane as u64;
                match kind {
                    MemKind::Read => {
                        self.mem_reads += 1;
                        self.bytes_read_requested += bytes;
                    }
                    MemKind::Write => {
                        self.mem_writes += 1;
                        self.bytes_written_requested += bytes;
                    }
                    MemKind::Atomic => {
                        self.mem_atomics += 1;
                        self.bytes_read_requested += bytes;
                        self.bytes_written_requested += bytes;
                    }
                }
                self.groups = self.groups.max(group_id + 1);
            }
            BlockRecord::Lds { group_id, kind, .. } => {
                let class = match kind {
                    MemKind::Read => InstClass::LdsLoad,
                    _ => InstClass::LdsStore,
                };
                self.inst.add(class, 1);
                self.lds_ops += 1;
                self.groups = self.groups.max(group_id + 1);
            }
        }
    }
}

impl EventSink for TraceStats {
    fn on_inst(&mut self, ctx: &GroupCtx, class: InstClass, count: u64) {
        self.inst.add(class, count);
        self.groups = self.groups.max(ctx.group_id + 1);
    }

    fn on_mem(&mut self, ctx: &GroupCtx, access: &MemAccess) {
        let class = match access.kind {
            MemKind::Read => InstClass::GlobalLoad,
            MemKind::Write => InstClass::GlobalStore,
            MemKind::Atomic => InstClass::GlobalAtomic,
        };
        self.inst.add(class, 1);
        self.active_lane_sum += access.active_lanes() as u64;
        match access.kind {
            MemKind::Read => {
                self.mem_reads += 1;
                self.bytes_read_requested += access.requested_bytes();
            }
            MemKind::Write => {
                self.mem_writes += 1;
                self.bytes_written_requested += access.requested_bytes();
            }
            MemKind::Atomic => {
                self.mem_atomics += 1;
                // an atomic reads and writes its word
                self.bytes_read_requested += access.requested_bytes();
                self.bytes_written_requested += access.requested_bytes();
            }
        }
        self.groups = self.groups.max(ctx.group_id + 1);
    }

    fn on_lds(&mut self, ctx: &GroupCtx, access: &LdsAccess) {
        let class = match access.kind {
            MemKind::Read => InstClass::LdsLoad,
            _ => InstClass::LdsStore,
        };
        self.inst.add(class, 1);
        self.lds_ops += 1;
        self.groups = self.groups.max(ctx.group_id + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(id: u64) -> GroupCtx {
        GroupCtx { group_id: id }
    }

    #[test]
    fn class_counts_accumulate() {
        let mut c = ClassCounts::default();
        c.add(InstClass::ValuArith, 5);
        c.add(InstClass::ValuSpecial, 2);
        c.add(InstClass::Salu, 3);
        c.add(InstClass::Branch, 1);
        assert_eq!(c.valu(), 7);
        assert_eq!(c.salu(), 3);
        assert_eq!(c.total(), 11);
    }

    #[test]
    fn mem_events_count_as_instructions() {
        let mut s = TraceStats::default();
        let a = MemAccess::contiguous(MemKind::Read, 0, 64, 4);
        s.on_mem(&ctx(0), &a);
        assert_eq!(s.inst.get(InstClass::GlobalLoad), 1);
        assert_eq!(s.mem_reads, 1);
        assert_eq!(s.bytes_read_requested, 256);
        assert_eq!(s.total_group_insts(), 1);
    }

    #[test]
    fn atomics_count_read_and_write_bytes() {
        let mut s = TraceStats::default();
        let a = MemAccess::contiguous(MemKind::Atomic, 0, 32, 4);
        s.on_mem(&ctx(0), &a);
        assert_eq!(s.bytes_read_requested, 128);
        assert_eq!(s.bytes_written_requested, 128);
        assert_eq!(s.mem_atomics, 1);
    }

    #[test]
    fn groups_tracks_max_id() {
        let mut s = TraceStats::default();
        s.on_inst(&ctx(7), InstClass::ValuArith, 1);
        s.on_inst(&ctx(3), InstClass::ValuArith, 1);
        assert_eq!(s.groups, 8);
    }

    #[test]
    fn columnar_fold_matches_per_record_fold() {
        use crate::trace::block::{BlockData, EventBlock};

        let mut b = EventBlock::default();
        b.push_inst(&ctx(0), InstClass::ValuArith, 7);
        b.push_inst(&ctx(0), InstClass::Branch, 2);
        b.push_mem(
            &ctx(1),
            &MemAccess::contiguous(MemKind::Read, 64, 8, 4),
        );
        b.push_mem(
            &ctx(1),
            &MemAccess::contiguous(MemKind::Atomic, 256, 4, 4),
        );
        b.push_lds(
            &ctx(2),
            &LdsAccess::from_lane_addrs(MemKind::Write, &[0, 4], 4),
        );
        b.push_mem(
            &ctx(2),
            &MemAccess::contiguous(MemKind::Write, 512, 3, 8),
        );

        for expansion in [1.0, 2.5] {
            let mut per = TraceStats::default();
            for rec in b.records() {
                per.on_record_scaled(&rec, expansion);
            }
            let mut col = TraceStats::default();
            col.fold_columns_scaled(&b.columns(), expansion);
            assert_eq!(per, col, "expansion {expansion}");
        }
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = TraceStats::default();
        let mut b = TraceStats::default();
        a.on_inst(&ctx(0), InstClass::ValuArith, 10);
        b.on_inst(&ctx(5), InstClass::Salu, 4);
        a.merge(&b);
        assert_eq!(a.inst.valu(), 10);
        assert_eq!(a.inst.salu(), 4);
        assert_eq!(a.groups, 6);
    }
}
