//! Synthetic trace generators: parameterized access patterns used by the
//! microbenchmarks (gpumembench analog), the memory-simulator tests, and
//! the "Global Memory Walls" construction of Fig. 4 (Ding & Williams'
//! strided-access diagnostic the paper applies in §7.1).
//!
//! The **scale fuzzer** half ([`SynthWorkload`], [`synth_dispatches`])
//! generates multi-dispatch workloads at any size — gather-heavy
//! (incompressible address columns), atomic-heavy (PIC-deposition-like
//! contention) and pathological-stride (sector-per-lane with jittered
//! bases) — which the bounded-memory streaming tests, the CI
//! `ulimit -v` smoke and `benches/hotpath.rs` use to build archives
//! much larger (or much nastier) than the science cases without
//! simulating any physics.

use super::event::{MemAccess, MemKind};
use super::recorded::RecordedDispatch;
use super::sink::EventSink;
use super::{for_each_group, TraceSource};
use crate::arch::InstClass;
use crate::util::Xoshiro256;

/// A pure streaming kernel: every thread reads `reads` arrays and writes
/// `writes` arrays at its own index (BabelStream's access pattern).
#[derive(Debug, Clone)]
pub struct StreamTrace {
    pub name: String,
    /// Elements (threads).
    pub n: u64,
    pub reads: u32,
    pub writes: u32,
    /// VALU instructions per thread-group between memory ops.
    pub valu_per_group: u64,
    pub bytes_per_lane: u8,
}

impl StreamTrace {
    /// The five BabelStream kernels.
    pub fn babelstream(op: &str, n: u64) -> StreamTrace {
        let (reads, writes, valu) = match op {
            "copy" => (1, 1, 1),
            "mul" => (1, 1, 2),
            "add" => (2, 1, 2),
            "triad" => (2, 1, 3),
            "dot" => (2, 0, 4),
            _ => panic!("unknown stream op {op}"),
        };
        StreamTrace {
            name: format!("stream_{op}"),
            n,
            reads,
            writes,
            valu_per_group: valu,
            bytes_per_lane: 4,
        }
    }

    /// Total bytes this kernel moves (requested).
    pub fn bytes(&self) -> u64 {
        self.n * self.bytes_per_lane as u64 * (self.reads + self.writes) as u64
    }
}

impl TraceSource for StreamTrace {
    fn name(&self) -> &str {
        &self.name
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let bpl = self.bytes_per_lane as u64;
        // Disjoint base offsets so distinct arrays never alias in cache.
        let array_span = self.n * bpl;
        for_each_group(self.n, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as u32;
            let base = range.start * bpl;
            for r in 0..self.reads {
                let arr_base = r as u64 * array_span;
                sink.on_mem(
                    ctx,
                    &MemAccess::contiguous(
                        MemKind::Read,
                        arr_base + base,
                        lanes,
                        self.bytes_per_lane,
                    ),
                );
            }
            if self.valu_per_group > 0 {
                sink.on_inst(ctx, InstClass::ValuArith, self.valu_per_group);
            }
            for w in 0..self.writes {
                let arr_base = (self.reads + w) as u64 * array_span;
                sink.on_mem(
                    ctx,
                    &MemAccess::contiguous(
                        MemKind::Write,
                        arr_base + base,
                        lanes,
                        self.bytes_per_lane,
                    ),
                );
            }
        });
    }
}

/// Strided kernel: lane i of group g reads `base + (g*gs + i) * stride`.
/// With stride ≥ 32B every lane hits its own sector — the "global memory
/// wall" worst case (32 transactions per warp-load on NVIDIA).
#[derive(Debug, Clone)]
pub struct StridedTrace {
    pub name: String,
    pub n: u64,
    pub stride: u64,
    pub bytes_per_lane: u8,
}

impl TraceSource for StridedTrace {
    fn name(&self) -> &str {
        &self.name
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        for_each_group(self.n, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as u32;
            let base = range.start * self.stride;
            sink.on_mem(
                ctx,
                &MemAccess::strided(
                    MemKind::Read,
                    base,
                    lanes,
                    self.stride,
                    self.bytes_per_lane,
                ),
            );
            sink.on_inst(ctx, InstClass::ValuArith, 2);
        });
    }
}

/// Uniform-random gather over a working set of `span` bytes — exercises
/// cache capacity behaviour and the scatter-bandwidth calibration point.
#[derive(Debug, Clone)]
pub struct RandomTrace {
    pub name: String,
    pub n: u64,
    pub span: u64,
    pub bytes_per_lane: u8,
    pub seed: u64,
}

impl TraceSource for RandomTrace {
    fn name(&self) -> &str {
        &self.name
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let slots = self.span / self.bytes_per_lane as u64;
        let mut lane_addrs = Vec::with_capacity(group_size as usize);
        for_each_group(self.n, group_size, |ctx, range| {
            lane_addrs.clear();
            for _ in range {
                lane_addrs
                    .push(rng.below(slots) * self.bytes_per_lane as u64);
            }
            sink.on_mem(
                ctx,
                &MemAccess::gather(
                    MemKind::Read,
                    &lane_addrs,
                    self.bytes_per_lane,
                ),
            );
            sink.on_inst(ctx, InstClass::ValuArith, 4);
        });
    }
}

// ------------------------------------------------------------- fuzzer

/// Workload families of the scale fuzzer. Each is deliberately nasty
/// for a different part of the archive/replay stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthWorkload {
    /// Uniform-random gathers over a working set proportional to the
    /// thread count: the address column is incompressible, so the
    /// archive stays near raw size and streaming replay is dominated
    /// by plain section I/O.
    Gather,
    /// Contiguous reads plus clustered atomic gathers over a small
    /// slot table (current-deposition caricature): high-conflict
    /// atomics for the L1 engines, RLE-friendly kind/length columns.
    Atomic,
    /// Sector-per-lane strides from per-group jittered bases: worst
    /// case for coalescing *and* for delta-varint (the jitter defeats
    /// small-delta encoding), with page-crossing strides.
    Stride,
}

impl SynthWorkload {
    pub const ALL: [SynthWorkload; 3] = [
        SynthWorkload::Gather,
        SynthWorkload::Atomic,
        SynthWorkload::Stride,
    ];

    /// CLI spelling (`--case gather|atomic|stride`).
    pub fn parse(s: &str) -> Option<SynthWorkload> {
        match s {
            "gather" => Some(SynthWorkload::Gather),
            "atomic" => Some(SynthWorkload::Atomic),
            "stride" => Some(SynthWorkload::Stride),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SynthWorkload::Gather => "gather",
            SynthWorkload::Atomic => "atomic",
            SynthWorkload::Stride => "stride",
        }
    }

    /// A size-parameterized instance: `n` threads, deterministic in
    /// `(workload, n, seed)`.
    pub fn case(self, n: u64, seed: u64) -> SynthCase {
        SynthCase {
            name: format!("synth_{}", self.label()),
            workload: self,
            n,
            seed,
        }
    }
}

/// One size-parameterized fuzzer kernel (a [`TraceSource`] — record,
/// archive or replay it like any other).
#[derive(Debug, Clone)]
pub struct SynthCase {
    pub name: String,
    pub workload: SynthWorkload,
    /// Threads (each group contributes a fixed access bundle, so the
    /// decoded trace size scales linearly in `n`).
    pub n: u64,
    pub seed: u64,
}

impl TraceSource for SynthCase {
    fn name(&self) -> &str {
        &self.name
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let mut lane_addrs =
            Vec::with_capacity(group_size as usize);
        match self.workload {
            SynthWorkload::Gather => {
                // working set ≫ any cache, 8B lanes
                let slots = (self.n * 16).max(1 << 17);
                for_each_group(self.n, group_size, |ctx, range| {
                    for _ in 0..2 {
                        lane_addrs.clear();
                        for _ in range.clone() {
                            lane_addrs.push(rng.below(slots) * 8);
                        }
                        sink.on_mem(
                            ctx,
                            &MemAccess::gather(
                                MemKind::Read,
                                &lane_addrs,
                                8,
                            ),
                        );
                    }
                    sink.on_inst(ctx, InstClass::ValuArith, 6);
                    lane_addrs.clear();
                    for _ in range.clone() {
                        lane_addrs.push(rng.below(slots) * 8);
                    }
                    sink.on_mem(
                        ctx,
                        &MemAccess::gather(
                            MemKind::Write,
                            &lane_addrs,
                            8,
                        ),
                    );
                });
            }
            SynthWorkload::Atomic => {
                // a small slot table concentrates conflicts
                let slots = 1u64 << 14;
                for_each_group(self.n, group_size, |ctx, range| {
                    let lanes = (range.end - range.start) as u32;
                    sink.on_mem(
                        ctx,
                        &MemAccess::contiguous(
                            MemKind::Read,
                            range.start * 4,
                            lanes,
                            4,
                        ),
                    );
                    sink.on_inst(ctx, InstClass::ValuArith, 4);
                    for _ in 0..3 {
                        lane_addrs.clear();
                        for _ in range.clone() {
                            lane_addrs.push(rng.below(slots) * 4);
                        }
                        sink.on_mem(
                            ctx,
                            &MemAccess::gather(
                                MemKind::Atomic,
                                &lane_addrs,
                                4,
                            ),
                        );
                    }
                });
            }
            SynthWorkload::Stride => {
                // sector-per-lane stride, base jittered per group so
                // consecutive groups' addresses have large irregular
                // deltas
                let stride = 4096u64;
                let span = (self.n * stride).max(1 << 20);
                for_each_group(self.n, group_size, |ctx, range| {
                    let lanes = (range.end - range.start) as u32;
                    let base = rng.below(span);
                    sink.on_mem(
                        ctx,
                        &MemAccess::strided(
                            MemKind::Read,
                            base,
                            lanes,
                            stride,
                            4,
                        ),
                    );
                    sink.on_inst(ctx, InstClass::ValuArith, 2);
                    sink.on_mem(
                        ctx,
                        &MemAccess::strided(
                            MemKind::Write,
                            base ^ 0x2000,
                            lanes,
                            stride,
                            4,
                        ),
                    );
                });
            }
        }
    }
}

/// Record a multi-dispatch fuzzer workload: `dispatches` independent
/// kernels of `threads_per_dispatch` threads each, with per-dispatch
/// derived seeds (dispatch `i` is deterministic in `(workload, i,
/// seed)` — the same parameters always produce the bit-identical
/// trace, which the CI smoke's digest comparison relies on). Archive
/// the result with [`crate::trace::archive::write_case_archive_with`]
/// to build arbitrarily large test archives.
pub fn synth_dispatches(
    workload: SynthWorkload,
    threads_per_dispatch: u64,
    dispatches: u32,
    group_size: u32,
    seed: u64,
) -> Vec<RecordedDispatch> {
    (0..dispatches)
        .map(|i| {
            let mix = 0x9E37_79B9_7F4A_7C15u64
                .wrapping_mul(i as u64 + 1);
            let case = workload
                .case(threads_per_dispatch, seed ^ mix);
            RecordedDispatch::record(&case, group_size)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{collect_stats, BlockData};

    #[test]
    fn babelstream_copy_shape() {
        let t = StreamTrace::babelstream("copy", 1024);
        let s = collect_stats(&t, 64);
        assert_eq!(s.groups, 16);
        assert_eq!(s.mem_reads, 16);
        assert_eq!(s.mem_writes, 16);
        assert_eq!(s.bytes_read_requested, 4096);
        assert_eq!(s.bytes_written_requested, 4096);
    }

    #[test]
    fn babelstream_bytes_match_formula() {
        for op in ["copy", "mul", "add", "triad", "dot"] {
            let t = StreamTrace::babelstream(op, 4096);
            let s = collect_stats(&t, 32);
            assert_eq!(
                s.bytes_read_requested + s.bytes_written_requested,
                t.bytes(),
                "{op}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "unknown stream op")]
    fn unknown_op_panics() {
        StreamTrace::babelstream("nope", 8);
    }

    #[test]
    fn strided_touches_distinct_sectors() {
        let t = StridedTrace {
            name: "s".into(),
            n: 64,
            stride: 128,
            bytes_per_lane: 4,
        };
        let s = collect_stats(&t, 64);
        assert_eq!(s.mem_reads, 1);
        assert_eq!(s.bytes_read_requested, 256);
    }

    #[test]
    fn random_trace_deterministic() {
        let t = RandomTrace {
            name: "r".into(),
            n: 256,
            span: 1 << 20,
            bytes_per_lane: 4,
            seed: 9,
        };
        let a = collect_stats(&t, 64);
        let b = collect_stats(&t, 64);
        assert_eq!(a, b);
    }

    #[test]
    fn warp_vs_wavefront_group_counts() {
        let t = StreamTrace::babelstream("copy", 2048);
        assert_eq!(collect_stats(&t, 32).groups, 64);
        assert_eq!(collect_stats(&t, 64).groups, 32);
    }

    #[test]
    fn fuzzer_workloads_are_deterministic() {
        for w in SynthWorkload::ALL {
            let a = collect_stats(&w.case(512, 7), 64);
            let b = collect_stats(&w.case(512, 7), 64);
            assert_eq!(a, b, "{}", w.label());
            let c = collect_stats(&w.case(512, 8), 64);
            // same shape, different addresses: the aggregate byte
            // counts agree but the traces differ (proven at the
            // archive level by the streaming tests)
            assert_eq!(a.groups, c.groups, "{}", w.label());
        }
    }

    #[test]
    fn fuzzer_size_scales_linearly_in_threads() {
        for w in SynthWorkload::ALL {
            let small = collect_stats(&w.case(1024, 3), 64);
            let big = collect_stats(&w.case(4096, 3), 64);
            assert_eq!(big.groups, 4 * small.groups, "{}", w.label());
            assert_eq!(
                big.mem_reads,
                4 * small.mem_reads,
                "{}",
                w.label()
            );
        }
    }

    #[test]
    fn atomic_workload_is_atomic_heavy() {
        let s = collect_stats(&SynthWorkload::Atomic.case(1024, 1), 64);
        assert!(s.mem_atomics > 0);
        assert!(
            s.mem_atomics >= 3 * s.mem_reads,
            "atomics must dominate: {} atomics vs {} reads",
            s.mem_atomics,
            s.mem_reads
        );
    }

    #[test]
    fn gather_workload_is_gather_heavy() {
        let s = collect_stats(&SynthWorkload::Gather.case(1024, 1), 64);
        assert_eq!(s.mem_reads, 2 * s.groups);
        assert_eq!(s.mem_writes, s.groups);
        assert_eq!(s.mem_atomics, 0);
    }

    #[test]
    fn synth_dispatches_vary_by_dispatch() {
        let ds = synth_dispatches(SynthWorkload::Gather, 256, 3, 64, 5);
        assert_eq!(ds.len(), 3);
        for d in &ds {
            assert_eq!(d.kernel, "synth_gather");
            assert!(!d.blocks.is_empty());
        }
        // per-dispatch seeds: same workload, different addresses
        let a: Vec<u64> = ds[0].blocks[0]
            .columns()
            .addrs
            .iter()
            .copied()
            .take(8)
            .collect();
        let b: Vec<u64> = ds[1].blocks[0]
            .columns()
            .addrs
            .iter()
            .copied()
            .take(8)
            .collect();
        assert_ne!(a, b, "dispatch seeds must differ");
        // and fully reproducible
        let again =
            synth_dispatches(SynthWorkload::Gather, 256, 3, 64, 5);
        let a2: Vec<u64> = again[0].blocks[0]
            .columns()
            .addrs
            .iter()
            .copied()
            .take(8)
            .collect();
        assert_eq!(a, a2, "same params must reproduce bit-identically");
    }
}
