//! Synthetic trace generators: parameterized access patterns used by the
//! microbenchmarks (gpumembench analog), the memory-simulator tests, and
//! the "Global Memory Walls" construction of Fig. 4 (Ding & Williams'
//! strided-access diagnostic the paper applies in §7.1).

use super::event::{MemAccess, MemKind};
use super::sink::EventSink;
use super::{for_each_group, TraceSource};
use crate::arch::InstClass;
use crate::util::Xoshiro256;

/// A pure streaming kernel: every thread reads `reads` arrays and writes
/// `writes` arrays at its own index (BabelStream's access pattern).
#[derive(Debug, Clone)]
pub struct StreamTrace {
    pub name: String,
    /// Elements (threads).
    pub n: u64,
    pub reads: u32,
    pub writes: u32,
    /// VALU instructions per thread-group between memory ops.
    pub valu_per_group: u64,
    pub bytes_per_lane: u8,
}

impl StreamTrace {
    /// The five BabelStream kernels.
    pub fn babelstream(op: &str, n: u64) -> StreamTrace {
        let (reads, writes, valu) = match op {
            "copy" => (1, 1, 1),
            "mul" => (1, 1, 2),
            "add" => (2, 1, 2),
            "triad" => (2, 1, 3),
            "dot" => (2, 0, 4),
            _ => panic!("unknown stream op {op}"),
        };
        StreamTrace {
            name: format!("stream_{op}"),
            n,
            reads,
            writes,
            valu_per_group: valu,
            bytes_per_lane: 4,
        }
    }

    /// Total bytes this kernel moves (requested).
    pub fn bytes(&self) -> u64 {
        self.n * self.bytes_per_lane as u64 * (self.reads + self.writes) as u64
    }
}

impl TraceSource for StreamTrace {
    fn name(&self) -> &str {
        &self.name
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let bpl = self.bytes_per_lane as u64;
        // Disjoint base offsets so distinct arrays never alias in cache.
        let array_span = self.n * bpl;
        for_each_group(self.n, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as u32;
            let base = range.start * bpl;
            for r in 0..self.reads {
                let arr_base = r as u64 * array_span;
                sink.on_mem(
                    ctx,
                    &MemAccess::contiguous(
                        MemKind::Read,
                        arr_base + base,
                        lanes,
                        self.bytes_per_lane,
                    ),
                );
            }
            if self.valu_per_group > 0 {
                sink.on_inst(ctx, InstClass::ValuArith, self.valu_per_group);
            }
            for w in 0..self.writes {
                let arr_base = (self.reads + w) as u64 * array_span;
                sink.on_mem(
                    ctx,
                    &MemAccess::contiguous(
                        MemKind::Write,
                        arr_base + base,
                        lanes,
                        self.bytes_per_lane,
                    ),
                );
            }
        });
    }
}

/// Strided kernel: lane i of group g reads `base + (g*gs + i) * stride`.
/// With stride ≥ 32B every lane hits its own sector — the "global memory
/// wall" worst case (32 transactions per warp-load on NVIDIA).
#[derive(Debug, Clone)]
pub struct StridedTrace {
    pub name: String,
    pub n: u64,
    pub stride: u64,
    pub bytes_per_lane: u8,
}

impl TraceSource for StridedTrace {
    fn name(&self) -> &str {
        &self.name
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        for_each_group(self.n, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as u32;
            let base = range.start * self.stride;
            sink.on_mem(
                ctx,
                &MemAccess::strided(
                    MemKind::Read,
                    base,
                    lanes,
                    self.stride,
                    self.bytes_per_lane,
                ),
            );
            sink.on_inst(ctx, InstClass::ValuArith, 2);
        });
    }
}

/// Uniform-random gather over a working set of `span` bytes — exercises
/// cache capacity behaviour and the scatter-bandwidth calibration point.
#[derive(Debug, Clone)]
pub struct RandomTrace {
    pub name: String,
    pub n: u64,
    pub span: u64,
    pub bytes_per_lane: u8,
    pub seed: u64,
}

impl TraceSource for RandomTrace {
    fn name(&self) -> &str {
        &self.name
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let slots = self.span / self.bytes_per_lane as u64;
        let mut lane_addrs = Vec::with_capacity(group_size as usize);
        for_each_group(self.n, group_size, |ctx, range| {
            lane_addrs.clear();
            for _ in range {
                lane_addrs
                    .push(rng.below(slots) * self.bytes_per_lane as u64);
            }
            sink.on_mem(
                ctx,
                &MemAccess::gather(
                    MemKind::Read,
                    &lane_addrs,
                    self.bytes_per_lane,
                ),
            );
            sink.on_inst(ctx, InstClass::ValuArith, 4);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::collect_stats;

    #[test]
    fn babelstream_copy_shape() {
        let t = StreamTrace::babelstream("copy", 1024);
        let s = collect_stats(&t, 64);
        assert_eq!(s.groups, 16);
        assert_eq!(s.mem_reads, 16);
        assert_eq!(s.mem_writes, 16);
        assert_eq!(s.bytes_read_requested, 4096);
        assert_eq!(s.bytes_written_requested, 4096);
    }

    #[test]
    fn babelstream_bytes_match_formula() {
        for op in ["copy", "mul", "add", "triad", "dot"] {
            let t = StreamTrace::babelstream(op, 4096);
            let s = collect_stats(&t, 32);
            assert_eq!(
                s.bytes_read_requested + s.bytes_written_requested,
                t.bytes(),
                "{op}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "unknown stream op")]
    fn unknown_op_panics() {
        StreamTrace::babelstream("nope", 8);
    }

    #[test]
    fn strided_touches_distinct_sectors() {
        let t = StridedTrace {
            name: "s".into(),
            n: 64,
            stride: 128,
            bytes_per_lane: 4,
        };
        let s = collect_stats(&t, 64);
        assert_eq!(s.mem_reads, 1);
        assert_eq!(s.bytes_read_requested, 256);
    }

    #[test]
    fn random_trace_deterministic() {
        let t = RandomTrace {
            name: "r".into(),
            n: 256,
            span: 1 << 20,
            bytes_per_lane: 4,
            seed: 9,
        };
        let a = collect_stats(&t, 64);
        let b = collect_stats(&t, 64);
        assert_eq!(a, b);
    }

    #[test]
    fn warp_vs_wavefront_group_counts() {
        let t = StreamTrace::babelstream("copy", 2048);
        assert_eq!(collect_stats(&t, 32).groups, 64);
        assert_eq!(collect_stats(&t, 64).groups, 32);
    }
}
