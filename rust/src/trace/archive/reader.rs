//! Archive reader: memory-map a `.rtrc` file and replay it zero-copy.
//!
//! [`MappedCaseTrace::open`] validates the **whole** file up front —
//! header, meta and index checksums, every column section's checksum,
//! every coded enum byte, and the structural invariants replay relies
//! on (tape/stream count agreement, access payloads inside the address
//! arena, lane counts within [`MAX_LANES`], non-zero access widths).
//! Corruption of any kind is a clean `anyhow` error here; after `open`
//! succeeds, replay through [`MappedBlock`]'s [`BlockData`] impl is
//! infallible — no deserialization, no copies, shared page cache
//! across processes.
//!
//! **Format v2** sections may be compressed (delta+varint / RLE, see
//! [`super::codec`]). Raw sections keep the original zero-copy mapped
//! path; compressed sections are decoded **once at open** into a
//! pooled per-archive decode arena (an 8-aligned owned buffer shared
//! by every decoded section of the file), reconstructing the exact v1
//! byte image — so the semantic validation and the hoisted
//! [`BlockData::columns`] view are identical for both storage forms,
//! and replay cost after `open` is the same plain-slice scan either
//! way. v1 files (all sections raw) remain fully readable.
//!
//! [`ArchiveInfo::scan`] is the cheap sibling used by `rocline
//! trace-info`: it reads only the header, meta and index (a few KB)
//! and never touches the column data.
//!
//! [`StreamingCaseTrace`] is the **out-of-core** tier: its `open` is
//! as cheap as the scan (header + meta + index only, via `pread` — no
//! mapping, so it works under an address-space cap smaller than the
//! file), and each dispatch's sections are read, checksum-verified,
//! decoded and semantically validated *on demand* into a pooled
//! per-dispatch arena that is recycled after replay. Every check
//! `MappedCaseTrace` performs at open runs here per dispatch instead,
//! with the same error vocabulary — corruption simply surfaces at
//! decode time rather than at open. Peak memory is a couple of
//! dispatch arenas (the replay driver double-buffers decode against
//! replay), not the decoded file.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::codec::{self, Encoding};
use super::format::{
    align_up, class_from_u8, fnv1a, kind_from_u8, tag_from_u8, Cursor,
    COLUMNS, COLUMN_WIDTHS, ENDIAN_TAG, ENDIAN_TAG_SWAPPED, EXTENSION,
    FORMAT_VERSION, HEADER_LEN, MAGIC, MIN_FORMAT_VERSION,
};
use super::format::ALL_COLUMNS_MASK;
use super::mmap::{ArchiveBuf, OwnedBytes};
use crate::arch::InstClass;
use crate::obs;
use crate::trace::block::{BlockData, Tag};
use crate::trace::recorded::{split_half_groups, RecordedDispatch};
use crate::trace::{MemKind, MAX_LANES};
use crate::util::pool::{lock_recover, Prefetch};

/// Parsed, checksum-verified fixed header.
struct Header {
    version: u32,
    base_group_size: u32,
    dispatch_count: u32,
    case_key: u64,
    meta_len: u64,
    index_off: u64,
    index_len: u64,
}

fn parse_header(bytes: &[u8]) -> anyhow::Result<Header> {
    // the format is little-endian on disk and replayed via native-
    // endian column views; a big-endian host must not get past open
    // (the writer is equally LE, so its archives would be unreadable
    // everywhere else too)
    anyhow::ensure!(
        cfg!(target_endian = "little"),
        "trace archives are little-endian and this build targets a \
         big-endian host; zero-copy replay is unsupported here"
    );
    anyhow::ensure!(
        bytes.len() >= HEADER_LEN,
        "corrupt archive: file shorter than the {HEADER_LEN}-byte \
         header ({} bytes)",
        bytes.len()
    );
    let mut c = Cursor::new(&bytes[..HEADER_LEN]);
    let magic = c.bytes(8)?;
    anyhow::ensure!(
        magic == MAGIC,
        "not a rocline trace archive (bad magic)"
    );
    let version = c.u32()?;
    anyhow::ensure!(
        (MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version),
        "unsupported trace archive format version {version} (this \
         build reads versions \
         {MIN_FORMAT_VERSION}..={FORMAT_VERSION}); re-record with \
         `rocline record`"
    );
    let endian = c.u32()?;
    if endian == ENDIAN_TAG_SWAPPED {
        anyhow::bail!(
            "trace archive endianness mismatch: written on a \
             big-endian machine, archives are not portable across \
             endianness; re-record with `rocline record`"
        );
    }
    anyhow::ensure!(
        endian == ENDIAN_TAG,
        "corrupt archive: bad endianness tag {endian:#010x}"
    );
    let base_group_size = c.u32()?;
    let dispatch_count = c.u32()?;
    let case_key = c.u64()?;
    let meta_len = c.u64()?;
    let index_off = c.u64()?;
    let index_len = c.u64()?;
    let stored_sum = c.u64()?;
    let computed = fnv1a(&bytes[..HEADER_LEN - 8]);
    anyhow::ensure!(
        stored_sum == computed,
        "corrupt archive: header checksum mismatch"
    );
    Ok(Header {
        version,
        base_group_size,
        dispatch_count,
        case_key,
        meta_len,
        index_off,
        index_len,
    })
}

/// Parsed meta section: (manifest line, field energy, kinetic energy).
fn parse_meta(bytes: &[u8]) -> anyhow::Result<(String, f64, f64)> {
    anyhow::ensure!(
        bytes.len() >= 4 + 8 + 8 + 8,
        "corrupt archive: meta section too short ({} bytes)",
        bytes.len()
    );
    let body = &bytes[..bytes.len() - 8];
    let mut tail = Cursor::new(&bytes[bytes.len() - 8..]);
    anyhow::ensure!(
        tail.u64()? == fnv1a(body),
        "corrupt archive: meta checksum mismatch"
    );
    let mut c = Cursor::new(body);
    let mlen = c.u32()? as usize;
    let manifest = std::str::from_utf8(c.bytes(mlen)?)
        .map_err(|_| {
            anyhow::anyhow!("corrupt archive: manifest is not UTF-8")
        })?
        .to_string();
    let field = c.f64()?;
    let kinetic = c.f64()?;
    anyhow::ensure!(
        c.remaining() == 0,
        "corrupt archive: {} trailing meta bytes",
        c.remaining()
    );
    Ok((manifest, field, kinetic))
}

/// One block's index entry, as stored. For v1 files every section is
/// [`Encoding::Raw`] and the stored length equals the raw length
/// derived from the element counts; v2 stores both fields explicitly.
struct RawBlockIndex {
    n_records: u32,
    n_inst: u32,
    n_acc: u32,
    n_addr: u32,
    col_enc: [Encoding; COLUMNS],
    col_off: [u64; COLUMNS],
    /// Stored (possibly encoded) byte length of each section.
    col_len: [u64; COLUMNS],
    col_sum: [u64; COLUMNS],
}

/// Verify the index checksum and parse its entries (version-aware).
fn parse_index(
    bytes: &[u8],
    dispatch_count: u32,
    version: u32,
) -> anyhow::Result<Vec<(String, Vec<RawBlockIndex>)>> {
    anyhow::ensure!(
        bytes.len() >= 8,
        "corrupt archive: index section too short"
    );
    let body = &bytes[..bytes.len() - 8];
    let mut tail = Cursor::new(&bytes[bytes.len() - 8..]);
    anyhow::ensure!(
        tail.u64()? == fnv1a(body),
        "corrupt archive: index checksum mismatch"
    );
    let mut c = Cursor::new(body);
    let mut out = Vec::new();
    for _ in 0..dispatch_count {
        let klen = c.u16()? as usize;
        let kernel = std::str::from_utf8(c.bytes(klen)?)
            .map_err(|_| {
                anyhow::anyhow!(
                    "corrupt archive: kernel name is not UTF-8"
                )
            })?
            .to_string();
        let nblocks = c.u32()?;
        let mut blocks = Vec::new();
        for _ in 0..nblocks {
            let mut e = RawBlockIndex {
                n_records: c.u32()?,
                n_inst: c.u32()?,
                n_acc: c.u32()?,
                n_addr: c.u32()?,
                col_enc: [Encoding::Raw; COLUMNS],
                col_off: [0; COLUMNS],
                col_len: [0; COLUMNS],
                col_sum: [0; COLUMNS],
            };
            if version >= 2 {
                for enc in e.col_enc.iter_mut() {
                    let b = c.u8()?;
                    *enc = Encoding::from_u8(b).ok_or_else(|| {
                        anyhow::anyhow!(
                            "corrupt archive: unknown section \
                             encoding byte {b}"
                        )
                    })?;
                }
                for len in e.col_len.iter_mut() {
                    *len = c.u64()?;
                }
            }
            for off in e.col_off.iter_mut() {
                *off = c.u64()?;
            }
            for sum in e.col_sum.iter_mut() {
                *sum = c.u64()?;
            }
            for col in 0..COLUMNS {
                let raw = raw_len_bytes(&e, col);
                if version < 2 {
                    e.col_len[col] = raw;
                } else if e.col_enc[col] == Encoding::Raw {
                    // a raw section's stored length is not a free
                    // variable — it must equal the count-derived one
                    anyhow::ensure!(
                        e.col_len[col] == raw,
                        "corrupt archive: raw column {col} stored \
                         length {} disagrees with its element count \
                         ({raw} bytes)",
                        e.col_len[col]
                    );
                }
            }
            blocks.push(e);
        }
        out.push((kernel, blocks));
    }
    anyhow::ensure!(
        c.remaining() == 0,
        "corrupt archive: {} trailing index bytes",
        c.remaining()
    );
    Ok(out)
}

/// Per-column element count, by wire position.
fn elem_count(e: &RawBlockIndex, c: usize) -> u64 {
    match c {
        0 | 1 => e.n_records as u64, // tags, group_ids
        2 | 3 => e.n_inst as u64,    // inst_class, inst_count
        4..=7 => e.n_acc as u64,     // acc_kind/bpl/off/len
        _ => e.n_addr as u64,        // addrs
    }
}

/// Per-column **raw** (decoded) byte length, by wire position.
fn raw_len_bytes(e: &RawBlockIndex, c: usize) -> u64 {
    elem_count(e, c) * COLUMN_WIDTHS[c].bytes() as u64
}

/// One block whose columns live in the mapped file (raw sections) or
/// in the archive's shared decode arena (compressed sections, decoded
/// once at open). Replays through [`BlockData`] exactly like an owned
/// [`crate::trace::EventBlock`] — the engines cannot tell the
/// difference (and the round-trip tests prove the counters can't
/// either).
pub struct MappedBlock {
    buf: Arc<ArchiveBuf>,
    /// Pooled decode arena shared by all of this archive's blocks
    /// (empty for all-raw files).
    arena: Arc<OwnedBytes>,
    n_records: u32,
    n_inst: u32,
    n_acc: u32,
    n_addr: u32,
    /// Per column: byte offset into the mapped file (raw sections) or
    /// into the decode arena (bit set in [`MappedBlock::arena_mask`]).
    col_off: [u64; COLUMNS],
    /// Bit `c` set ⇔ column `c` lives in the decode arena.
    arena_mask: u16,
}

/// Reinterpret `len * size_of::<T>()` bytes at `off` as a `&[T]`.
///
/// # Safety
///
/// The caller must guarantee, for the given `bytes`/`off`/`len`, that
/// the range is in bounds and `off` is aligned for `T` (the archive
/// open path validated bounds and 8-byte section alignment for both
/// the mapped file and the decode arena), and that every value in the
/// range is a valid `T` bit pattern — trivially so for the integer
/// columns, and guaranteed for the `repr(u8)` enum columns (`Tag`,
/// `MemKind`, `InstClass`) because open validated every coded byte
/// against the wire encoding, which equals the enums' discriminants.
///
/// The enum-typed views additionally lean on the mapping-stability
/// contract stated in [`super::mmap`]: archives are written
/// atomically (temp + rename) and never modified in place, so the
/// bytes validated at open are the bytes replay sees. An external
/// actor rewriting an archive *in place* under a live mapping is
/// outside that contract — it was already unsupported (truncation
/// could fault any mmap consumer, and silently-changed column data
/// would corrupt counters), and with typed enum slices it is
/// undefined behavior rather than a deterministic decode panic.
/// (Arena-backed columns are immune: they are private heap copies.)
#[inline]
unsafe fn col_slice<T>(bytes: &[u8], off: u64, len: usize) -> &[T] {
    debug_assert!(
        off as usize + len * std::mem::size_of::<T>() <= bytes.len()
    );
    debug_assert_eq!(off as usize % std::mem::align_of::<T>(), 0);
    std::slice::from_raw_parts(
        bytes.as_ptr().add(off as usize).cast::<T>(),
        len,
    )
}

impl MappedBlock {
    /// The byte slice column `c`'s decoded image lives in: the mapped
    /// file for raw sections, the decode arena for compressed ones.
    #[inline]
    fn col_bytes<'a>(
        &self,
        mapped: &'a [u8],
        arena: &'a [u8],
        c: usize,
    ) -> &'a [u8] {
        if self.arena_mask & (1 << c) != 0 {
            arena
        } else {
            mapped
        }
    }
}

impl BlockData for MappedBlock {
    fn len(&self) -> usize {
        self.n_records as usize
    }

    fn addr_words(&self) -> usize {
        self.n_addr as usize
    }

    /// The hoisted column view: **one** `Arc` deref per storage
    /// (mapped file + decode arena), then nine zero-copy slices. The
    /// pre-columnar per-record accessors paid that resolution for
    /// every record of every scan — this is the `speedup/columnar_scan`
    /// win, and it holds for raw-mapped and decoded columns alike.
    fn columns(&self) -> crate::trace::block::Columns<'_> {
        let mapped = self.buf.bytes();
        let arena = self.arena.bytes();
        let n_rec = self.n_records as usize;
        let n_inst = self.n_inst as usize;
        let n_acc = self.n_acc as usize;
        let n_addr = self.n_addr as usize;
        // SAFETY: every offset/length pair was bounds-, alignment- and
        // checksum-validated at open (decoded sections re-validated
        // post-decode), and every enum byte was checked against its
        // wire encoding there (see `col_slice`).
        unsafe {
            crate::trace::block::Columns {
                tags: col_slice::<Tag>(
                    self.col_bytes(mapped, arena, 0),
                    self.col_off[0],
                    n_rec,
                ),
                group_ids: col_slice::<u64>(
                    self.col_bytes(mapped, arena, 1),
                    self.col_off[1],
                    n_rec,
                ),
                inst_class: col_slice::<InstClass>(
                    self.col_bytes(mapped, arena, 2),
                    self.col_off[2],
                    n_inst,
                ),
                inst_count: col_slice::<u64>(
                    self.col_bytes(mapped, arena, 3),
                    self.col_off[3],
                    n_inst,
                ),
                acc_kind: col_slice::<MemKind>(
                    self.col_bytes(mapped, arena, 4),
                    self.col_off[4],
                    n_acc,
                ),
                acc_bpl: col_slice::<u8>(
                    self.col_bytes(mapped, arena, 5),
                    self.col_off[5],
                    n_acc,
                ),
                acc_off: col_slice::<u32>(
                    self.col_bytes(mapped, arena, 6),
                    self.col_off[6],
                    n_acc,
                ),
                acc_len: col_slice::<u8>(
                    self.col_bytes(mapped, arena, 7),
                    self.col_off[7],
                    n_acc,
                ),
                addrs: col_slice::<u64>(
                    self.col_bytes(mapped, arena, 8),
                    self.col_off[8],
                    n_addr,
                ),
            }
        }
    }
}

/// One kernel dispatch of a mapped archive.
pub struct MappedDispatch {
    pub kernel: String,
    pub blocks: Vec<MappedBlock>,
}

/// A whole case archive, mapped and validated — the disk tier's
/// counterpart of [`crate::coordinator::CaseTrace`].
pub struct MappedCaseTrace {
    manifest: String,
    version: u32,
    base_group_size: u32,
    case_key: u64,
    final_field_energy: f64,
    final_kinetic_energy: f64,
    bytes_on_disk: u64,
    decoded_bytes: u64,
    mapped: bool,
    dispatches: Vec<MappedDispatch>,
    /// Lazily derived half-group-size form (warp-width targets), like
    /// the in-memory [`crate::coordinator::CaseTrace`]'s cache.
    halved: Mutex<Option<Arc<Vec<RecordedDispatch>>>>,
}

impl MappedCaseTrace {
    /// Map `path` and validate everything (see the module docs).
    pub fn open(path: &Path) -> anyhow::Result<MappedCaseTrace> {
        let _s = obs::span("archive.open");
        if let Some(e) = crate::fault::io_error("archive.read") {
            anyhow::bail!("trace archive {}: {e}", path.display());
        }
        Self::open_inner(path).map_err(|e| {
            anyhow::anyhow!("trace archive {}: {e}", path.display())
        })
    }

    fn open_inner(path: &Path) -> anyhow::Result<MappedCaseTrace> {
        let file = File::open(path)?;
        let buf = Arc::new(ArchiveBuf::load(&file)?);
        let bytes = buf.bytes();
        let h = parse_header(bytes)?;

        let file_len = bytes.len() as u64;
        let meta_end = (HEADER_LEN as u64).checked_add(h.meta_len);
        anyhow::ensure!(
            meta_end.is_some_and(|end| {
                end <= file_len && align_up(end) <= h.index_off
            }) && h
                .index_off
                .checked_add(h.index_len)
                .is_some_and(|end| end == file_len),
            "corrupt archive: section table out of bounds \
             (meta {} bytes, index {}+{}, file {} bytes)",
            h.meta_len,
            h.index_off,
            h.index_len,
            file_len
        );
        let (manifest, final_field_energy, final_kinetic_energy) =
            parse_meta(
                &bytes[HEADER_LEN..HEADER_LEN + h.meta_len as usize],
            )?;
        let index = parse_index(
            &bytes[h.index_off as usize
                ..(h.index_off + h.index_len) as usize],
            h.dispatch_count,
            h.version,
        )?;

        // -- column validation + one-shot decode --------------------
        // stored-form checks (bounds, alignment, checksums) first;
        // compressed sections then decode into the shared arena; the
        // semantic validation (enum codes, tape agreement, payload
        // invariants) runs on the decoded images of both forms.
        let mut arena = OwnedBytes::with_capacity(0);
        // cumulative decode budget: per-section caps alone would let a
        // small file with a corrupt index (many block entries, each
        // claiming huge element counts for tiny RLE streams) grow the
        // arena without bound — an OOM abort instead of the clean
        // error the format promises. Legitimate amplification is
        // bounded (delta-varint ≤8x; the RLE byte columns amplify more
        // but are absolutely small), so a generous multiple of the
        // file size rejects only bombs.
        let arena_budget = (256u64 << 20)
            .saturating_add(file_len.saturating_mul(64));
        let mut dispatches = Vec::with_capacity(index.len());
        for (kernel, raw_blocks) in index {
            let mut blocks = Vec::with_capacity(raw_blocks.len());
            for e in raw_blocks {
                let block = load_block(
                    bytes,
                    &e,
                    h.index_off,
                    &buf,
                    &mut arena,
                    arena_budget,
                )
                .map_err(|err| {
                    anyhow::anyhow!("dispatch {kernel}: {err}")
                })?;
                blocks.push(block);
            }
            dispatches.push(MappedDispatch { kernel, blocks });
        }

        // the arena grew while blocks were loaded; now that it is
        // final, share it (blocks were created with placeholder
        // arenas — patch them to the shared one)
        let decoded_bytes = arena.bytes().len() as u64;
        let arena = Arc::new(arena);
        for d in dispatches.iter_mut() {
            for b in d.blocks.iter_mut() {
                b.arena = Arc::clone(&arena);
            }
        }

        Ok(MappedCaseTrace {
            manifest,
            version: h.version,
            base_group_size: h.base_group_size,
            case_key: h.case_key,
            final_field_energy,
            final_kinetic_energy,
            bytes_on_disk: file_len,
            decoded_bytes,
            mapped: buf.is_mapped(),
            dispatches,
            halved: Mutex::new(None),
        })
    }

    pub fn manifest(&self) -> &str {
        &self.manifest
    }

    /// The file's format version (1 or 2).
    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn base_group_size(&self) -> u32 {
        self.base_group_size
    }

    pub fn case_key(&self) -> u64 {
        self.case_key
    }

    pub fn final_field_energy(&self) -> f64 {
        self.final_field_energy
    }

    pub fn final_kinetic_energy(&self) -> f64 {
        self.final_kinetic_energy
    }

    pub fn bytes_on_disk(&self) -> u64 {
        self.bytes_on_disk
    }

    /// Bytes held by the pooled decode arena (0 for all-raw files) —
    /// the memory cost of compressed sections at replay.
    pub fn decoded_bytes(&self) -> u64 {
        self.decoded_bytes
    }

    /// Whether the archive is a true file mapping (false: the aligned
    /// read fallback on platforms without mmap).
    pub fn is_mapped(&self) -> bool {
        self.mapped
    }

    /// The base-width dispatches, replayable zero-copy.
    pub fn dispatches(&self) -> &[MappedDispatch] {
        &self.dispatches
    }

    pub fn dispatch_count(&self) -> usize {
        self.dispatches.len()
    }

    /// The derived half-group-size dispatch list (V100's 32-lane
    /// warps), computed from the mapped columns once and cached —
    /// exactly [`crate::coordinator::CaseTrace`]'s behaviour for the
    /// in-memory tier.
    pub fn halved_dispatches(
        &self,
        half: u32,
    ) -> Arc<Vec<RecordedDispatch>> {
        assert_eq!(
            half * 2,
            self.base_group_size,
            "archived at group size {}, cannot replay at {half}",
            self.base_group_size
        );
        let mut slot = crate::util::pool::lock_recover(&self.halved);
        if let Some(h) = slot.as_ref() {
            return Arc::clone(h);
        }
        let derived: Vec<RecordedDispatch> = self
            .dispatches
            .iter()
            .map(|d| RecordedDispatch {
                kernel: d.kernel.clone(),
                blocks: Arc::new(split_half_groups(&d.blocks, half)),
            })
            .collect();
        let arc = Arc::new(derived);
        *slot = Some(Arc::clone(&arc));
        arc
    }
}

/// Validate one block's stored sections, decode its compressed ones
/// into `arena`, run the semantic validation over the decoded images,
/// and assemble the [`MappedBlock`]. (The returned block carries a
/// placeholder arena handle; `open_inner` patches in the shared one
/// once every block has been loaded.)
fn load_block(
    bytes: &[u8],
    e: &RawBlockIndex,
    data_end: u64,
    buf: &Arc<ArchiveBuf>,
    arena: &mut OwnedBytes,
    arena_budget: u64,
) -> anyhow::Result<MappedBlock> {
    // -- stored form: bounds, alignment, checksums ------------------
    for c in 0..COLUMNS {
        let off = e.col_off[c];
        let len = e.col_len[c];
        let padded = align_up(len);
        anyhow::ensure!(
            off % 8 == 0,
            "corrupt archive: column {c} misaligned (offset {off})"
        );
        let end = off.checked_add(padded);
        anyhow::ensure!(
            off >= HEADER_LEN as u64
                && end.is_some_and(|end| end <= data_end),
            "corrupt archive: column {c} out of bounds \
             ({off}+{padded} vs data end {data_end})"
        );
        let span = &bytes[off as usize..(off + padded) as usize];
        anyhow::ensure!(
            fnv1a(span) == e.col_sum[c],
            "corrupt archive: column {c} checksum mismatch \
             (flipped bytes at offset {off}..{})",
            off + padded
        );
    }

    // -- decode compressed sections into the shared arena -----------
    // a raw section's size is bounded by the file itself; a compressed
    // one is bounded only by its *claimed* element count, so cap the
    // decoded size before allocating — a legal block (≤ ~4k records,
    // ≤ 64 lanes per access) stays under ~3 MiB, so 256 MiB rejects
    // only decompression bombs from corrupt indexes, never real data
    const MAX_DECODED_SECTION: u64 = 256 << 20;
    let mut col_off = e.col_off;
    let mut arena_mask = 0u16;
    let mut decode_buf: Vec<u8> = Vec::new();
    for c in 0..COLUMNS {
        if e.col_enc[c] == Encoding::Raw {
            continue;
        }
        anyhow::ensure!(
            raw_len_bytes(e, c) <= MAX_DECODED_SECTION,
            "corrupt archive: column {c} claims {} decoded bytes \
             (limit {MAX_DECODED_SECTION})",
            raw_len_bytes(e, c)
        );
        anyhow::ensure!(
            (arena.bytes().len() as u64)
                .saturating_add(raw_len_bytes(e, c))
                <= arena_budget,
            "corrupt archive: decoded sections exceed the archive's \
             decode budget ({arena_budget} bytes) — decompression \
             bomb?"
        );
        let stored = &bytes[e.col_off[c] as usize..]
            [..e.col_len[c] as usize];
        decode_buf.clear();
        codec::decode(
            stored,
            e.col_enc[c],
            elem_count(e, c) as usize,
            COLUMN_WIDTHS[c],
            &mut decode_buf,
        )
        .map_err(|err| {
            anyhow::anyhow!("column {c}: {err}")
        })?;
        debug_assert_eq!(
            decode_buf.len() as u64,
            raw_len_bytes(e, c),
            "codec::decode produces exactly the raw image"
        );
        col_off[c] = arena.push_aligned(&decode_buf) as u64;
        arena_mask |= 1 << c;
    }

    // -- semantic validation over the decoded images ----------------
    // (the arena is not mutated past this point, so one shared
    // reborrow serves every resolved column)
    let arena_bytes = arena.bytes();
    validate_block_semantics(e, |c: usize| {
        let base = if arena_mask & (1 << c) != 0 {
            arena_bytes
        } else {
            bytes
        };
        &base[col_off[c] as usize..]
            [..raw_len_bytes(e, c) as usize]
    })?;

    Ok(MappedBlock {
        buf: Arc::clone(buf),
        arena: Arc::new(OwnedBytes::default()),
        n_records: e.n_records,
        n_inst: e.n_inst,
        n_acc: e.n_acc,
        n_addr: e.n_addr,
        col_off,
        arena_mask,
    })
}

/// The structural invariants replay relies on, checked over the
/// **decoded** (v1-image) columns — shared by the mapped tier (at
/// open) and the streaming tier (per dispatch). `resolve(c)` returns
/// column `c`'s decoded image, exactly `raw_len_bytes(e, c)` bytes.
fn validate_block_semantics<'a>(
    e: &RawBlockIndex,
    resolve: impl Fn(usize) -> &'a [u8],
) -> anyhow::Result<()> {
    // enum codes and tape/stream agreement
    let tags = resolve(0);
    let (mut inst, mut acc) = (0u32, 0u32);
    for &t in tags {
        match tag_from_u8(t) {
            Some(Tag::Inst) => inst += 1,
            Some(_) => acc += 1,
            None => anyhow::bail!(
                "corrupt archive: invalid tag byte {t}"
            ),
        }
    }
    anyhow::ensure!(
        inst == e.n_inst && acc == e.n_acc,
        "corrupt archive: tape disagrees with stream counts \
         ({inst}/{acc} vs {}/{})",
        e.n_inst,
        e.n_acc
    );
    for &b in resolve(2) {
        anyhow::ensure!(
            class_from_u8(b).is_some(),
            "corrupt archive: invalid instruction class byte {b}"
        );
    }
    for &b in resolve(4) {
        anyhow::ensure!(
            kind_from_u8(b).is_some(),
            "corrupt archive: invalid memory kind byte {b}"
        );
    }

    // access payload invariants the replay engines rely on
    let bpls = resolve(5);
    let lens = resolve(7);
    let offs_raw = resolve(6);
    for i in 0..e.n_acc as usize {
        let off = u32::from_le_bytes([
            offs_raw[i * 4],
            offs_raw[i * 4 + 1],
            offs_raw[i * 4 + 2],
            offs_raw[i * 4 + 3],
        ]) as u64;
        let len = lens[i] as u64;
        anyhow::ensure!(
            len <= MAX_LANES as u64
                && off + len <= e.n_addr as u64,
            "corrupt archive: access {i} payload out of range \
             ({off}+{len} of {} addr words)",
            e.n_addr
        );
        anyhow::ensure!(
            bpls[i] > 0,
            "corrupt archive: access {i} has zero bytes-per-lane"
        );
    }
    Ok(())
}

/// Positioned exact read — `pread(2)` on unix, so concurrent decode
/// jobs never race over a shared file cursor and no address-space is
/// spent mapping the file.
fn read_at_exact(
    file: &File,
    buf: &mut [u8],
    off: u64,
) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        std::os::unix::fs::FileExt::read_exact_at(file, buf, off)
    }
    #[cfg(not(unix))]
    {
        // seek + read through the shared handle: fine here because
        // the replay driver keeps at most one decode job in flight
        use std::io::{Read, Seek, SeekFrom};
        let mut f = file;
        f.seek(SeekFrom::Start(off))?;
        f.read_exact(buf)
    }
}

/// One dispatch decoded out-of-core. Its blocks' columns all live in
/// one pooled arena owned by this handle; hand it back through
/// [`StreamingCaseTrace::recycle`] once replayed so the storage is
/// reused for a later dispatch (dropping it instead just frees the
/// memory — correct, but defeats the pool).
pub struct StreamedDispatch {
    pub kernel: String,
    pub blocks: Vec<MappedBlock>,
    arena: Arc<OwnedBytes>,
    arena_capacity: u64,
}

/// A case archive opened for **out-of-core streaming replay** — the
/// bounded-memory sibling of [`MappedCaseTrace`] (see the module
/// docs for the tier split). `open` costs one index read; column
/// data is decoded per dispatch by [`Self::decode_dispatch`] /
/// [`Self::replay`] and recycled afterwards. `Send + Sync`: decode
/// jobs run on the shared worker pool.
pub struct StreamingCaseTrace {
    path: PathBuf,
    file: File,
    manifest: String,
    version: u32,
    base_group_size: u32,
    case_key: u64,
    final_field_energy: f64,
    final_kinetic_energy: f64,
    bytes_on_disk: u64,
    /// End of the column-data region (= index offset).
    data_end: u64,
    index: Vec<(String, Vec<RawBlockIndex>)>,
    /// Sections stored under a non-raw encoding, whole archive.
    encoded_sections: u64,
    /// Cumulative decode budget per dispatch (decompression-bomb
    /// guard — same formula as the mapped tier's whole-file budget,
    /// so anything the mapped tier accepts, this tier accepts).
    arena_budget: u64,
    /// Shared never-dereferenced [`ArchiveBuf`] backing streamed
    /// blocks: with every column in the arena, `MappedBlock` never
    /// resolves a file byte through it.
    empty_buf: Arc<ArchiveBuf>,
    /// Recycled arena storage (8-aligned words), bounded by the
    /// replay driver's decode-ahead depth.
    word_pool: Mutex<Vec<Vec<u64>>>,
    /// Recycled section read/decode scratch buffers.
    scratch_pool: Mutex<Vec<Vec<u8>>>,
    /// Decode-buffer bytes currently live (dispatch arenas in
    /// flight) — transient scratch is counted at its peak inside
    /// `decode_dispatch` and released when pooled.
    cur_bytes: AtomicU64,
    /// High-water mark of `cur_bytes` — what `mem/replay_peak_rss`
    /// reports.
    peak_bytes: AtomicU64,
    /// How many dispatch arenas were returned to `word_pool` for
    /// reuse — the buffer-pool recycle gauge `/v1/status` surfaces.
    recycles: AtomicU64,
}

impl StreamingCaseTrace {
    /// Open `path` for streaming: reads and validates header, meta
    /// and index only (a few KB, like [`ArchiveInfo::scan`]); column
    /// checksums and semantic validation run per dispatch at decode
    /// time.
    pub fn open(path: &Path) -> anyhow::Result<StreamingCaseTrace> {
        let _s = obs::span("archive.open");
        if let Some(e) = crate::fault::io_error("archive.read") {
            anyhow::bail!("trace archive {}: {e}", path.display());
        }
        Self::open_inner(path).map_err(|e| {
            anyhow::anyhow!("trace archive {}: {e}", path.display())
        })
    }

    fn open_inner(path: &Path) -> anyhow::Result<StreamingCaseTrace> {
        let file = File::open(path)?;
        let file_len = file.metadata()?.len();
        let mut head = vec![0u8; HEADER_LEN];
        read_at_exact(&file, &mut head, 0).map_err(|_| {
            anyhow::anyhow!(
                "corrupt archive: file shorter than the \
                 {HEADER_LEN}-byte header ({file_len} bytes)"
            )
        })?;
        let h = parse_header(&head)?;
        let meta_end = (HEADER_LEN as u64).checked_add(h.meta_len);
        anyhow::ensure!(
            meta_end.is_some_and(|end| {
                end <= file_len && align_up(end) <= h.index_off
            }) && h
                .index_off
                .checked_add(h.index_len)
                .is_some_and(|end| end == file_len),
            "corrupt archive: section table out of bounds \
             (meta {} bytes, index {}+{}, file {} bytes)",
            h.meta_len,
            h.index_off,
            h.index_len,
            file_len
        );
        let mut meta = vec![0u8; h.meta_len as usize];
        read_at_exact(&file, &mut meta, HEADER_LEN as u64)?;
        let (manifest, final_field_energy, final_kinetic_energy) =
            parse_meta(&meta)?;
        let mut index_bytes = vec![0u8; h.index_len as usize];
        read_at_exact(&file, &mut index_bytes, h.index_off)?;
        let index =
            parse_index(&index_bytes, h.dispatch_count, h.version)?;
        let encoded_sections = index
            .iter()
            .flat_map(|(_, bs)| bs.iter())
            .map(|e| {
                e.col_enc
                    .iter()
                    .filter(|&&enc| enc != Encoding::Raw)
                    .count() as u64
            })
            .sum();
        Ok(StreamingCaseTrace {
            path: path.to_path_buf(),
            file,
            manifest,
            version: h.version,
            base_group_size: h.base_group_size,
            case_key: h.case_key,
            final_field_energy,
            final_kinetic_energy,
            bytes_on_disk: file_len,
            data_end: h.index_off,
            index,
            encoded_sections,
            arena_budget: (256u64 << 20)
                .saturating_add(file_len.saturating_mul(64)),
            empty_buf: Arc::new(ArchiveBuf::Owned(
                OwnedBytes::default(),
            )),
            word_pool: Mutex::new(Vec::new()),
            scratch_pool: Mutex::new(Vec::new()),
            cur_bytes: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
            recycles: AtomicU64::new(0),
        })
    }

    pub fn manifest(&self) -> &str {
        &self.manifest
    }

    pub fn version(&self) -> u32 {
        self.version
    }

    pub fn base_group_size(&self) -> u32 {
        self.base_group_size
    }

    pub fn case_key(&self) -> u64 {
        self.case_key
    }

    pub fn final_field_energy(&self) -> f64 {
        self.final_field_energy
    }

    pub fn final_kinetic_energy(&self) -> f64 {
        self.final_kinetic_energy
    }

    pub fn bytes_on_disk(&self) -> u64 {
        self.bytes_on_disk
    }

    pub fn dispatch_count(&self) -> usize {
        self.index.len()
    }

    /// Kernel name of dispatch `i` (no decode).
    pub fn kernel(&self, i: usize) -> &str {
        &self.index[i].0
    }

    /// How many sections (whole archive) are stored under a non-raw
    /// encoding. 0 ⇔ replaying resident via mmap is pure zero-copy —
    /// the store's auto policy uses this to pick the tier.
    pub fn encoded_sections(&self) -> u64 {
        self.encoded_sections
    }

    /// Decode-buffer bytes currently live (see [`Self::recycle`]).
    pub fn current_decode_bytes(&self) -> u64 {
        self.cur_bytes.load(Ordering::Relaxed)
    }

    /// High-water mark of decode-buffer bytes over the trace's
    /// lifetime — the streaming tier's bounded-memory claim, and the
    /// `mem/replay_peak_rss` bench metric.
    pub fn peak_decode_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    /// How many dispatch arenas have been returned to the buffer
    /// pool for reuse (see [`Self::recycle`]).
    pub fn buffer_recycles(&self) -> u64 {
        self.recycles.load(Ordering::Relaxed)
    }

    fn track(&self, bytes: u64) {
        let cur =
            self.cur_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak_bytes.fetch_max(cur, Ordering::Relaxed);
    }

    fn untrack(&self, bytes: u64) {
        self.cur_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Read, verify and decode dispatch `i` into a pooled arena. Every
    /// stored-form check (alignment, bounds, checksum), decode guard
    /// (section cap, decode budget) and semantic check the mapped tier
    /// runs at open runs here, with identical error text; I/O errors
    /// (e.g. a file truncated after open) surface as clean column-
    /// level read errors.
    pub fn decode_dispatch(
        &self,
        i: usize,
    ) -> anyhow::Result<StreamedDispatch> {
        let _s = obs::span("stream.decode");
        self.decode_dispatch_inner(i).map_err(|e| {
            anyhow::anyhow!(
                "trace archive {}: {e}",
                self.path.display()
            )
        })
    }

    fn decode_dispatch_inner(
        &self,
        i: usize,
    ) -> anyhow::Result<StreamedDispatch> {
        let (kernel, entries) = &self.index[i];
        let mut scratch = lock_recover(&self.scratch_pool)
            .pop()
            .unwrap_or_default();
        let mut decode_buf = lock_recover(&self.scratch_pool)
            .pop()
            .unwrap_or_default();
        let mut arena = OwnedBytes::from_recycled(
            lock_recover(&self.word_pool).pop().unwrap_or_default(),
        );

        let mut blocks = Vec::with_capacity(entries.len());
        let mut failure = None;
        for e in entries {
            match self.decode_block(
                e,
                &mut scratch,
                &mut decode_buf,
                &mut arena,
            ) {
                Ok(b) => blocks.push(b),
                Err(err) => {
                    failure = Some(anyhow::anyhow!(
                        "dispatch {kernel}: {err}"
                    ));
                    break;
                }
            }
        }

        // account the dispatch's footprint at its peak (arena +
        // transient scratch), then release the scratch share as the
        // buffers return to the pool; the arena share stays charged
        // until `recycle`
        let arena_capacity = arena.capacity_bytes() as u64;
        let transient =
            (scratch.capacity() + decode_buf.capacity()) as u64;
        obs::observe_bytes(
            "stream.decode.bytes",
            arena_capacity + transient,
        );
        self.track(arena_capacity + transient);
        self.untrack(transient);
        {
            let mut pool = lock_recover(&self.scratch_pool);
            pool.push(scratch);
            pool.push(decode_buf);
        }
        if let Some(err) = failure {
            self.untrack(arena_capacity);
            self.recycles.fetch_add(1, Ordering::Relaxed);
            lock_recover(&self.word_pool).push(arena.into_words());
            return Err(err);
        }

        let arena = Arc::new(arena);
        for b in blocks.iter_mut() {
            b.arena = Arc::clone(&arena);
        }
        Ok(StreamedDispatch {
            kernel: kernel.clone(),
            blocks,
            arena,
            arena_capacity,
        })
    }

    /// The streaming analogue of [`load_block`]: same three stages
    /// (stored-form checks, decode, semantic validation), but over
    /// `pread` bytes and with **every** column — raw or compressed —
    /// copied into the per-dispatch arena (nothing may borrow the
    /// file: there is no mapping).
    fn decode_block(
        &self,
        e: &RawBlockIndex,
        scratch: &mut Vec<u8>,
        decode_buf: &mut Vec<u8>,
        arena: &mut OwnedBytes,
    ) -> anyhow::Result<MappedBlock> {
        const MAX_DECODED_SECTION: u64 = 256 << 20;
        let data_end = self.data_end;
        let mut col_off = [0u64; COLUMNS];
        for c in 0..COLUMNS {
            let off = e.col_off[c];
            let len = e.col_len[c];
            let padded = align_up(len);
            anyhow::ensure!(
                off % 8 == 0,
                "corrupt archive: column {c} misaligned \
                 (offset {off})"
            );
            let end = off.checked_add(padded);
            anyhow::ensure!(
                off >= HEADER_LEN as u64
                    && end.is_some_and(|end| end <= data_end),
                "corrupt archive: column {c} out of bounds \
                 ({off}+{padded} vs data end {data_end})"
            );
            scratch.clear();
            scratch.resize(padded as usize, 0);
            read_at_exact(&self.file, scratch, off).map_err(
                |err| {
                    anyhow::anyhow!(
                        "column {c}: read {padded} bytes at offset \
                         {off}: {err}"
                    )
                },
            )?;
            anyhow::ensure!(
                fnv1a(scratch) == e.col_sum[c],
                "corrupt archive: column {c} checksum mismatch \
                 (flipped bytes at offset {off}..{})",
                off + padded
            );
            let stored = &scratch[..len as usize];
            if e.col_enc[c] == Encoding::Raw {
                // stored length == raw length (parse_index enforced
                // it), so the padded read *is* the decoded image
                col_off[c] = arena.push_aligned(stored) as u64;
            } else {
                anyhow::ensure!(
                    raw_len_bytes(e, c) <= MAX_DECODED_SECTION,
                    "corrupt archive: column {c} claims {} decoded \
                     bytes (limit {MAX_DECODED_SECTION})",
                    raw_len_bytes(e, c)
                );
                anyhow::ensure!(
                    (arena.bytes().len() as u64)
                        .saturating_add(raw_len_bytes(e, c))
                        <= self.arena_budget,
                    "corrupt archive: decoded sections exceed the \
                     archive's decode budget ({} bytes) — \
                     decompression bomb?",
                    self.arena_budget
                );
                decode_buf.clear();
                codec::decode(
                    stored,
                    e.col_enc[c],
                    elem_count(e, c) as usize,
                    COLUMN_WIDTHS[c],
                    decode_buf,
                )
                .map_err(|err| {
                    anyhow::anyhow!("column {c}: {err}")
                })?;
                debug_assert_eq!(
                    decode_buf.len() as u64,
                    raw_len_bytes(e, c),
                    "codec::decode produces exactly the raw image"
                );
                col_off[c] = arena.push_aligned(decode_buf) as u64;
            }
        }

        // semantic validation over the arena images (identical to
        // the mapped tier's, via the shared helper)
        let arena_bytes = arena.bytes();
        validate_block_semantics(e, |c: usize| {
            &arena_bytes[col_off[c] as usize..]
                [..raw_len_bytes(e, c) as usize]
        })?;

        Ok(MappedBlock {
            buf: Arc::clone(&self.empty_buf),
            arena: Arc::new(OwnedBytes::default()), // patched by caller
            n_records: e.n_records,
            n_inst: e.n_inst,
            n_acc: e.n_acc,
            n_addr: e.n_addr,
            col_off,
            arena_mask: ALL_COLUMNS_MASK,
        })
    }

    /// Return a replayed dispatch's arena storage to the pool. Safe
    /// to skip (the memory is just freed instead of reused), but a
    /// dispatch that is never recycled keeps its bytes counted in
    /// [`Self::current_decode_bytes`].
    pub fn recycle(&self, d: StreamedDispatch) {
        let StreamedDispatch {
            blocks,
            arena,
            arena_capacity,
            ..
        } = d;
        drop(blocks);
        self.untrack(arena_capacity);
        if let Ok(owned) = Arc::try_unwrap(arena) {
            self.recycles.fetch_add(1, Ordering::Relaxed);
            lock_recover(&self.word_pool).push(owned.into_words());
        }
    }

    /// Stream every dispatch through `consume` with one-dispatch
    /// **decode-ahead**: while the caller replays dispatch `N`,
    /// dispatch `N+1` decodes on the shared [`WorkerPool`] — the
    /// decompression/replay overlap that mirrors the engine's L1/L2
    /// double buffer. At most two dispatch arenas are ever live.
    ///
    /// [`WorkerPool`]: crate::util::pool::WorkerPool
    pub fn replay(
        self: &Arc<Self>,
        mut consume: impl FnMut(&StreamedDispatch),
    ) -> anyhow::Result<()> {
        let n = self.dispatch_count();
        if n == 0 {
            return Ok(());
        }
        let spawn = |i: usize| {
            let t = Arc::clone(self);
            Prefetch::spawn(move || t.decode_dispatch(i))
        };
        let mut pending = Some(spawn(0));
        for i in 0..n {
            let d = pending
                .take()
                .expect("decode job scheduled each iteration")
                .join()?;
            if i + 1 < n {
                pending = Some(spawn(i + 1));
            }
            consume(&d);
            self.recycle(d);
        }
        Ok(())
    }
}

/// Per-column storage totals of one archive (raw vs stored bytes and
/// how many sections chose a non-raw encoding) — what `trace-info`
/// reports as compression ratios.
#[derive(Debug, Clone, Copy, Default)]
pub struct ColumnStats {
    /// Decoded (v1-image) bytes.
    pub raw_bytes: u64,
    /// Bytes actually stored on disk (= raw for raw sections).
    pub stored_bytes: u64,
    /// Sections of this column stored under a non-raw encoding.
    pub encoded_sections: u64,
    /// Total sections of this column.
    pub sections: u64,
}

impl ColumnStats {
    /// raw / stored; 1.0 for empty columns.
    pub fn ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            1.0
        } else {
            self.raw_bytes as f64 / self.stored_bytes as f64
        }
    }
}

/// Index-level summary of one archive (no column data touched).
pub struct ArchiveInfo {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub version: u32,
    pub case_key: u64,
    pub base_group_size: u32,
    pub manifest: String,
    pub dispatches: usize,
    pub blocks: u64,
    pub records: u64,
    pub addr_words: u64,
    /// Per wire column (see [`super::format::COLUMN_NAMES`]).
    pub columns: [ColumnStats; COLUMNS],
}

impl ArchiveInfo {
    /// Read header + meta + index only — cheap enough to run over a
    /// whole archive directory without deserializing any trace data.
    pub fn scan(path: &Path) -> anyhow::Result<ArchiveInfo> {
        Self::scan_inner(path).map_err(|e| {
            anyhow::anyhow!("trace archive {}: {e}", path.display())
        })
    }

    fn scan_inner(path: &Path) -> anyhow::Result<ArchiveInfo> {
        use std::io::{Read, Seek, SeekFrom};
        let mut file = File::open(path)?;
        let file_bytes = file.metadata()?.len();
        let mut head = vec![0u8; HEADER_LEN];
        file.read_exact(&mut head).map_err(|_| {
            anyhow::anyhow!(
                "corrupt archive: file shorter than the \
                 {HEADER_LEN}-byte header ({file_bytes} bytes)"
            )
        })?;
        let h = parse_header(&head)?;
        anyhow::ensure!(
            (HEADER_LEN as u64)
                .checked_add(h.meta_len)
                .is_some_and(|end| end <= file_bytes)
                && h.index_off
                    .checked_add(h.index_len)
                    .is_some_and(|end| end == file_bytes),
            "corrupt archive: section table out of bounds"
        );
        let mut meta = vec![0u8; h.meta_len as usize];
        file.read_exact(&mut meta)?;
        let (manifest, _, _) = parse_meta(&meta)?;
        file.seek(SeekFrom::Start(h.index_off))?;
        let mut index = vec![0u8; h.index_len as usize];
        file.read_exact(&mut index)?;
        let entries =
            parse_index(&index, h.dispatch_count, h.version)?;

        let mut blocks = 0u64;
        let mut records = 0u64;
        let mut addr_words = 0u64;
        let mut columns = [ColumnStats::default(); COLUMNS];
        for (_, bs) in &entries {
            blocks += bs.len() as u64;
            for b in bs {
                records += b.n_records as u64;
                addr_words += b.n_addr as u64;
                for (c, stat) in columns.iter_mut().enumerate() {
                    stat.raw_bytes += raw_len_bytes(b, c);
                    stat.stored_bytes += b.col_len[c];
                    stat.sections += 1;
                    if b.col_enc[c] != Encoding::Raw {
                        stat.encoded_sections += 1;
                    }
                }
            }
        }
        Ok(ArchiveInfo {
            path: path.to_path_buf(),
            file_bytes,
            version: h.version,
            case_key: h.case_key,
            base_group_size: h.base_group_size,
            manifest,
            dispatches: entries.len(),
            blocks,
            records,
            addr_words,
            columns,
        })
    }

    /// Scan every `.rtrc` file in `dir`, sorted by file name.
    pub fn scan_dir(dir: &Path) -> anyhow::Result<Vec<ArchiveInfo>> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| {
                anyhow::anyhow!(
                    "read archive dir {}: {e}",
                    dir.display()
                )
            })?
            .filter_map(|entry| entry.ok().map(|e| e.path()))
            .filter(|p| {
                p.extension().and_then(|x| x.to_str())
                    == Some(EXTENSION)
            })
            .collect();
        paths.sort();
        paths.iter().map(|p| ArchiveInfo::scan(p)).collect()
    }

    /// Case name parsed out of the manifest line (best effort — the
    /// manifest is `case name=<x> ...`).
    pub fn case_name(&self) -> &str {
        self.manifest
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("name="))
            .unwrap_or("?")
    }

    /// Total decoded (v1-image) column bytes.
    pub fn raw_column_bytes(&self) -> u64 {
        self.columns.iter().map(|c| c.raw_bytes).sum()
    }

    /// Total stored column bytes (what actually sits on disk).
    pub fn stored_column_bytes(&self) -> u64 {
        self.columns.iter().map(|c| c.stored_bytes).sum()
    }

    /// Overall column compression ratio (raw / stored; 1.0 when
    /// nothing is stored).
    pub fn compress_ratio(&self) -> f64 {
        let stored = self.stored_column_bytes();
        if stored == 0 {
            1.0
        } else {
            self.raw_column_bytes() as f64 / stored as f64
        }
    }

    /// Compression ratio of the address-arena column alone — the
    /// archive's dominant section, the one the ROADMAP's "~4x"
    /// estimate was about.
    pub fn addr_ratio(&self) -> f64 {
        self.columns[COLUMNS - 1].ratio()
    }

    /// One-line per-section encoding summary for `trace-info`, e.g.
    /// `addrs 4.1x dv · group_ids 7.8x dv · acc_len 62.1x rle`; only
    /// columns with at least one encoded section appear. Empty string
    /// for all-raw archives.
    pub fn encoding_summary(&self) -> String {
        let mut parts = Vec::new();
        for (c, stat) in self.columns.iter().enumerate() {
            if stat.encoded_sections == 0 {
                continue;
            }
            parts.push(format!(
                "{} {:.1}x {}",
                super::format::COLUMN_NAMES[c],
                stat.ratio(),
                COLUMN_WIDTHS[c].codec().label(),
            ));
        }
        parts.join(" · ")
    }
}
