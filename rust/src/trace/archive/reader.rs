//! Archive reader: memory-map a `.rtrc` file and replay it zero-copy.
//!
//! [`MappedCaseTrace::open`] validates the **whole** file up front —
//! header, meta and index checksums, every column section's checksum,
//! every coded enum byte, and the structural invariants replay relies
//! on (tape/stream count agreement, access payloads inside the address
//! arena, lane counts within [`MAX_LANES`], non-zero access widths).
//! Corruption of any kind is a clean `anyhow` error here; after `open`
//! succeeds, replay through [`MappedBlock`]'s [`BlockData`] impl is
//! infallible and borrows the mapped columns directly — no
//! deserialization, no copies, shared page cache across processes.
//!
//! [`ArchiveInfo::scan`] is the cheap sibling used by `rocline
//! trace-info`: it reads only the header, meta and index (a few KB)
//! and never touches the column data.

use std::fs::File;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::format::{
    align_up, class_from_u8, fnv1a, kind_from_u8, tag_from_u8, Cursor,
    COLUMNS, ENDIAN_TAG, ENDIAN_TAG_SWAPPED, EXTENSION,
    FORMAT_VERSION, HEADER_LEN, MAGIC,
};
use super::mmap::ArchiveBuf;
use crate::arch::InstClass;
use crate::trace::block::{BlockData, Tag};
use crate::trace::recorded::{split_half_groups, RecordedDispatch};
use crate::trace::{MemKind, MAX_LANES};

/// Parsed, checksum-verified fixed header.
struct Header {
    version: u32,
    base_group_size: u32,
    dispatch_count: u32,
    case_key: u64,
    meta_len: u64,
    index_off: u64,
    index_len: u64,
}

fn parse_header(bytes: &[u8]) -> anyhow::Result<Header> {
    // format v1 is little-endian on disk and replayed via native-
    // endian column views; a big-endian host must not get past open
    // (the writer is equally LE, so its archives would be unreadable
    // everywhere else too)
    anyhow::ensure!(
        cfg!(target_endian = "little"),
        "trace archives are little-endian (format v1) and this build \
         targets a big-endian host; zero-copy replay is unsupported \
         here"
    );
    anyhow::ensure!(
        bytes.len() >= HEADER_LEN,
        "corrupt archive: file shorter than the {HEADER_LEN}-byte \
         header ({} bytes)",
        bytes.len()
    );
    let mut c = Cursor::new(&bytes[..HEADER_LEN]);
    let magic = c.bytes(8)?;
    anyhow::ensure!(
        magic == MAGIC,
        "not a rocline trace archive (bad magic)"
    );
    let version = c.u32()?;
    anyhow::ensure!(
        version == FORMAT_VERSION,
        "unsupported trace archive format version {version} (this \
         build reads version {FORMAT_VERSION}); re-record with \
         `rocline record`"
    );
    let endian = c.u32()?;
    if endian == ENDIAN_TAG_SWAPPED {
        anyhow::bail!(
            "trace archive endianness mismatch: written on a \
             big-endian machine, archives are not portable across \
             endianness; re-record with `rocline record`"
        );
    }
    anyhow::ensure!(
        endian == ENDIAN_TAG,
        "corrupt archive: bad endianness tag {endian:#010x}"
    );
    let base_group_size = c.u32()?;
    let dispatch_count = c.u32()?;
    let case_key = c.u64()?;
    let meta_len = c.u64()?;
    let index_off = c.u64()?;
    let index_len = c.u64()?;
    let stored_sum = c.u64()?;
    let computed = fnv1a(&bytes[..HEADER_LEN - 8]);
    anyhow::ensure!(
        stored_sum == computed,
        "corrupt archive: header checksum mismatch"
    );
    Ok(Header {
        version,
        base_group_size,
        dispatch_count,
        case_key,
        meta_len,
        index_off,
        index_len,
    })
}

/// Parsed meta section: (manifest line, field energy, kinetic energy).
fn parse_meta(bytes: &[u8]) -> anyhow::Result<(String, f64, f64)> {
    anyhow::ensure!(
        bytes.len() >= 4 + 8 + 8 + 8,
        "corrupt archive: meta section too short ({} bytes)",
        bytes.len()
    );
    let body = &bytes[..bytes.len() - 8];
    let mut tail = Cursor::new(&bytes[bytes.len() - 8..]);
    anyhow::ensure!(
        tail.u64()? == fnv1a(body),
        "corrupt archive: meta checksum mismatch"
    );
    let mut c = Cursor::new(body);
    let mlen = c.u32()? as usize;
    let manifest = std::str::from_utf8(c.bytes(mlen)?)
        .map_err(|_| {
            anyhow::anyhow!("corrupt archive: manifest is not UTF-8")
        })?
        .to_string();
    let field = c.f64()?;
    let kinetic = c.f64()?;
    anyhow::ensure!(
        c.remaining() == 0,
        "corrupt archive: {} trailing meta bytes",
        c.remaining()
    );
    Ok((manifest, field, kinetic))
}

/// One block's index entry, as stored.
struct RawBlockIndex {
    n_records: u32,
    n_inst: u32,
    n_acc: u32,
    n_addr: u32,
    col_off: [u64; COLUMNS],
    col_sum: [u64; COLUMNS],
}

/// Verify the index checksum and parse its entries.
fn parse_index(
    bytes: &[u8],
    dispatch_count: u32,
) -> anyhow::Result<Vec<(String, Vec<RawBlockIndex>)>> {
    anyhow::ensure!(
        bytes.len() >= 8,
        "corrupt archive: index section too short"
    );
    let body = &bytes[..bytes.len() - 8];
    let mut tail = Cursor::new(&bytes[bytes.len() - 8..]);
    anyhow::ensure!(
        tail.u64()? == fnv1a(body),
        "corrupt archive: index checksum mismatch"
    );
    let mut c = Cursor::new(body);
    let mut out = Vec::new();
    for _ in 0..dispatch_count {
        let klen = c.u16()? as usize;
        let kernel = std::str::from_utf8(c.bytes(klen)?)
            .map_err(|_| {
                anyhow::anyhow!(
                    "corrupt archive: kernel name is not UTF-8"
                )
            })?
            .to_string();
        let nblocks = c.u32()?;
        let mut blocks = Vec::new();
        for _ in 0..nblocks {
            let mut e = RawBlockIndex {
                n_records: c.u32()?,
                n_inst: c.u32()?,
                n_acc: c.u32()?,
                n_addr: c.u32()?,
                col_off: [0; COLUMNS],
                col_sum: [0; COLUMNS],
            };
            for off in e.col_off.iter_mut() {
                *off = c.u64()?;
            }
            for sum in e.col_sum.iter_mut() {
                *sum = c.u64()?;
            }
            blocks.push(e);
        }
        out.push((kernel, blocks));
    }
    anyhow::ensure!(
        c.remaining() == 0,
        "corrupt archive: {} trailing index bytes",
        c.remaining()
    );
    Ok(out)
}

/// Per-column byte length, by wire position.
fn col_len_bytes(e: &RawBlockIndex, c: usize) -> u64 {
    match c {
        0 => e.n_records as u64,     // tags (u8)
        1 => e.n_records as u64 * 8, // group_ids (u64)
        2 => e.n_inst as u64,        // inst_class (u8)
        3 => e.n_inst as u64 * 8,    // inst_count (u64)
        4 => e.n_acc as u64,         // acc_kind (u8)
        5 => e.n_acc as u64,         // acc_bpl (u8)
        6 => e.n_acc as u64 * 4,     // acc_off (u32)
        7 => e.n_acc as u64,         // acc_len (u8)
        _ => e.n_addr as u64 * 8,    // addrs (u64)
    }
}

/// One block whose columns live in the mapped file. Replays through
/// [`BlockData`] exactly like an owned
/// [`crate::trace::EventBlock`] — the engines cannot tell the
/// difference (and the round-trip tests prove the counters can't
/// either).
pub struct MappedBlock {
    buf: Arc<ArchiveBuf>,
    n_records: u32,
    n_inst: u32,
    n_acc: u32,
    n_addr: u32,
    col_off: [u64; COLUMNS],
}

/// Reinterpret `len * size_of::<T>()` mapped bytes at `off` as a
/// `&[T]`.
///
/// # Safety
///
/// The caller must guarantee, for the given `bytes`/`off`/`len`, that
/// the range is in bounds and `off` is aligned for `T` (the archive
/// open path validated bounds and 8-byte section alignment), and that
/// every value in the range is a valid `T` bit pattern — trivially so
/// for the integer columns, and guaranteed for the `repr(u8)` enum
/// columns (`Tag`, `MemKind`, `InstClass`) because open validated
/// every coded byte against the wire encoding, which equals the enums'
/// discriminants.
///
/// The enum-typed views additionally lean on the mapping-stability
/// contract stated in [`super::mmap`]: archives are written
/// atomically (temp + rename) and never modified in place, so the
/// bytes validated at open are the bytes replay sees. An external
/// actor rewriting an archive *in place* under a live mapping is
/// outside that contract — it was already unsupported (truncation
/// could fault any mmap consumer, and silently-changed column data
/// would corrupt counters), and with typed enum slices it is
/// undefined behavior rather than a deterministic decode panic.
#[inline]
unsafe fn col_slice<T>(bytes: &[u8], off: u64, len: usize) -> &[T] {
    debug_assert!(
        off as usize + len * std::mem::size_of::<T>() <= bytes.len()
    );
    debug_assert_eq!(off as usize % std::mem::align_of::<T>(), 0);
    std::slice::from_raw_parts(
        bytes.as_ptr().add(off as usize).cast::<T>(),
        len,
    )
}

impl BlockData for MappedBlock {
    fn len(&self) -> usize {
        self.n_records as usize
    }

    fn addr_words(&self) -> usize {
        self.n_addr as usize
    }

    /// The hoisted column view: **one** `Arc` deref + storage-enum
    /// match (`buf.bytes()`), then nine zero-copy slices straight into
    /// the mapping. The pre-columnar per-record accessors paid that
    /// resolution for every record of every scan — this is the
    /// `speedup/columnar_scan` win.
    fn columns(&self) -> crate::trace::block::Columns<'_> {
        let bytes = self.buf.bytes();
        let n_rec = self.n_records as usize;
        let n_inst = self.n_inst as usize;
        let n_acc = self.n_acc as usize;
        let n_addr = self.n_addr as usize;
        // SAFETY: every offset/length pair was bounds-, alignment- and
        // checksum-validated at open, and every enum byte was checked
        // against its wire encoding there (see `col_slice`).
        unsafe {
            crate::trace::block::Columns {
                tags: col_slice::<Tag>(bytes, self.col_off[0], n_rec),
                group_ids: col_slice::<u64>(
                    bytes,
                    self.col_off[1],
                    n_rec,
                ),
                inst_class: col_slice::<InstClass>(
                    bytes,
                    self.col_off[2],
                    n_inst,
                ),
                inst_count: col_slice::<u64>(
                    bytes,
                    self.col_off[3],
                    n_inst,
                ),
                acc_kind: col_slice::<MemKind>(
                    bytes,
                    self.col_off[4],
                    n_acc,
                ),
                acc_bpl: col_slice::<u8>(
                    bytes,
                    self.col_off[5],
                    n_acc,
                ),
                acc_off: col_slice::<u32>(
                    bytes,
                    self.col_off[6],
                    n_acc,
                ),
                acc_len: col_slice::<u8>(
                    bytes,
                    self.col_off[7],
                    n_acc,
                ),
                addrs: col_slice::<u64>(
                    bytes,
                    self.col_off[8],
                    n_addr,
                ),
            }
        }
    }
}

/// One kernel dispatch of a mapped archive.
pub struct MappedDispatch {
    pub kernel: String,
    pub blocks: Vec<MappedBlock>,
}

/// A whole case archive, mapped and validated — the disk tier's
/// counterpart of [`crate::coordinator::CaseTrace`].
pub struct MappedCaseTrace {
    manifest: String,
    base_group_size: u32,
    case_key: u64,
    final_field_energy: f64,
    final_kinetic_energy: f64,
    bytes_on_disk: u64,
    mapped: bool,
    dispatches: Vec<MappedDispatch>,
    /// Lazily derived half-group-size form (warp-width targets), like
    /// the in-memory [`crate::coordinator::CaseTrace`]'s cache.
    halved: Mutex<Option<Arc<Vec<RecordedDispatch>>>>,
}

impl MappedCaseTrace {
    /// Map `path` and validate everything (see the module docs).
    pub fn open(path: &Path) -> anyhow::Result<MappedCaseTrace> {
        Self::open_inner(path).map_err(|e| {
            anyhow::anyhow!("trace archive {}: {e}", path.display())
        })
    }

    fn open_inner(path: &Path) -> anyhow::Result<MappedCaseTrace> {
        let file = File::open(path)?;
        let buf = Arc::new(ArchiveBuf::load(&file)?);
        let bytes = buf.bytes();
        let h = parse_header(bytes)?;

        let file_len = bytes.len() as u64;
        let meta_end = (HEADER_LEN as u64).checked_add(h.meta_len);
        anyhow::ensure!(
            meta_end.is_some_and(|end| {
                end <= file_len && align_up(end) <= h.index_off
            }) && h
                .index_off
                .checked_add(h.index_len)
                .is_some_and(|end| end == file_len),
            "corrupt archive: section table out of bounds \
             (meta {} bytes, index {}+{}, file {} bytes)",
            h.meta_len,
            h.index_off,
            h.index_len,
            file_len
        );
        let (manifest, final_field_energy, final_kinetic_energy) =
            parse_meta(
                &bytes[HEADER_LEN..HEADER_LEN + h.meta_len as usize],
            )?;
        let index = parse_index(
            &bytes[h.index_off as usize
                ..(h.index_off + h.index_len) as usize],
            h.dispatch_count,
        )?;

        // -- column validation: bounds, alignment, checksums, codes --
        let mut dispatches = Vec::with_capacity(index.len());
        for (kernel, raw_blocks) in index {
            let mut blocks = Vec::with_capacity(raw_blocks.len());
            for e in raw_blocks {
                validate_block(bytes, &e, h.index_off).map_err(
                    |err| {
                        anyhow::anyhow!("dispatch {kernel}: {err}")
                    },
                )?;
                blocks.push(MappedBlock {
                    buf: Arc::clone(&buf),
                    n_records: e.n_records,
                    n_inst: e.n_inst,
                    n_acc: e.n_acc,
                    n_addr: e.n_addr,
                    col_off: e.col_off,
                });
            }
            dispatches.push(MappedDispatch { kernel, blocks });
        }

        Ok(MappedCaseTrace {
            manifest,
            base_group_size: h.base_group_size,
            case_key: h.case_key,
            final_field_energy,
            final_kinetic_energy,
            bytes_on_disk: file_len,
            mapped: buf.is_mapped(),
            dispatches,
            halved: Mutex::new(None),
        })
    }

    pub fn manifest(&self) -> &str {
        &self.manifest
    }

    pub fn base_group_size(&self) -> u32 {
        self.base_group_size
    }

    pub fn case_key(&self) -> u64 {
        self.case_key
    }

    pub fn final_field_energy(&self) -> f64 {
        self.final_field_energy
    }

    pub fn final_kinetic_energy(&self) -> f64 {
        self.final_kinetic_energy
    }

    pub fn bytes_on_disk(&self) -> u64 {
        self.bytes_on_disk
    }

    /// Whether the archive is a true file mapping (false: the aligned
    /// read fallback on platforms without mmap).
    pub fn is_mapped(&self) -> bool {
        self.mapped
    }

    /// The base-width dispatches, replayable zero-copy.
    pub fn dispatches(&self) -> &[MappedDispatch] {
        &self.dispatches
    }

    pub fn dispatch_count(&self) -> usize {
        self.dispatches.len()
    }

    /// The derived half-group-size dispatch list (V100's 32-lane
    /// warps), computed from the mapped columns once and cached —
    /// exactly [`crate::coordinator::CaseTrace`]'s behaviour for the
    /// in-memory tier.
    pub fn halved_dispatches(
        &self,
        half: u32,
    ) -> Arc<Vec<RecordedDispatch>> {
        assert_eq!(
            half * 2,
            self.base_group_size,
            "archived at group size {}, cannot replay at {half}",
            self.base_group_size
        );
        let mut slot = self.halved.lock().unwrap();
        if let Some(h) = slot.as_ref() {
            return Arc::clone(h);
        }
        let derived: Vec<RecordedDispatch> = self
            .dispatches
            .iter()
            .map(|d| RecordedDispatch {
                kernel: d.kernel.clone(),
                blocks: Arc::new(split_half_groups(&d.blocks, half)),
            })
            .collect();
        let arc = Arc::new(derived);
        *slot = Some(Arc::clone(&arc));
        arc
    }
}

/// Structural validation of one block (bounds, alignment, per-column
/// checksums, enum codes, tape/stream agreement, payload invariants).
fn validate_block(
    bytes: &[u8],
    e: &RawBlockIndex,
    data_end: u64,
) -> anyhow::Result<()> {
    for c in 0..COLUMNS {
        let off = e.col_off[c];
        let len = col_len_bytes(e, c);
        let padded = align_up(len);
        anyhow::ensure!(
            off % 8 == 0,
            "corrupt archive: column {c} misaligned (offset {off})"
        );
        let end = off.checked_add(padded);
        anyhow::ensure!(
            off >= HEADER_LEN as u64
                && end.is_some_and(|end| end <= data_end),
            "corrupt archive: column {c} out of bounds \
             ({off}+{padded} vs data end {data_end})"
        );
        let span = &bytes[off as usize..(off + padded) as usize];
        anyhow::ensure!(
            fnv1a(span) == e.col_sum[c],
            "corrupt archive: column {c} checksum mismatch \
             (flipped bytes at offset {off}..{})",
            off + padded
        );
    }

    // enum codes and tape/stream agreement
    let tags = &bytes[e.col_off[0] as usize..]
        [..e.n_records as usize];
    let (mut inst, mut acc) = (0u32, 0u32);
    for &t in tags {
        match tag_from_u8(t) {
            Some(Tag::Inst) => inst += 1,
            Some(_) => acc += 1,
            None => anyhow::bail!(
                "corrupt archive: invalid tag byte {t}"
            ),
        }
    }
    anyhow::ensure!(
        inst == e.n_inst && acc == e.n_acc,
        "corrupt archive: tape disagrees with stream counts \
         ({inst}/{acc} vs {}/{})",
        e.n_inst,
        e.n_acc
    );
    let classes = &bytes[e.col_off[2] as usize..]
        [..e.n_inst as usize];
    for &b in classes {
        anyhow::ensure!(
            class_from_u8(b).is_some(),
            "corrupt archive: invalid instruction class byte {b}"
        );
    }
    let kinds =
        &bytes[e.col_off[4] as usize..][..e.n_acc as usize];
    for &b in kinds {
        anyhow::ensure!(
            kind_from_u8(b).is_some(),
            "corrupt archive: invalid memory kind byte {b}"
        );
    }

    // access payload invariants the replay engines rely on
    let bpls =
        &bytes[e.col_off[5] as usize..][..e.n_acc as usize];
    let lens =
        &bytes[e.col_off[7] as usize..][..e.n_acc as usize];
    let offs_raw = &bytes[e.col_off[6] as usize..]
        [..e.n_acc as usize * 4];
    for i in 0..e.n_acc as usize {
        let off = u32::from_le_bytes([
            offs_raw[i * 4],
            offs_raw[i * 4 + 1],
            offs_raw[i * 4 + 2],
            offs_raw[i * 4 + 3],
        ]) as u64;
        let len = lens[i] as u64;
        anyhow::ensure!(
            len <= MAX_LANES as u64
                && off + len <= e.n_addr as u64,
            "corrupt archive: access {i} payload out of range \
             ({off}+{len} of {} addr words)",
            e.n_addr
        );
        anyhow::ensure!(
            bpls[i] > 0,
            "corrupt archive: access {i} has zero bytes-per-lane"
        );
    }
    Ok(())
}

/// Index-level summary of one archive (no column data touched).
pub struct ArchiveInfo {
    pub path: PathBuf,
    pub file_bytes: u64,
    pub version: u32,
    pub case_key: u64,
    pub base_group_size: u32,
    pub manifest: String,
    pub dispatches: usize,
    pub blocks: u64,
    pub records: u64,
    pub addr_words: u64,
}

impl ArchiveInfo {
    /// Read header + meta + index only — cheap enough to run over a
    /// whole archive directory without deserializing any trace data.
    pub fn scan(path: &Path) -> anyhow::Result<ArchiveInfo> {
        Self::scan_inner(path).map_err(|e| {
            anyhow::anyhow!("trace archive {}: {e}", path.display())
        })
    }

    fn scan_inner(path: &Path) -> anyhow::Result<ArchiveInfo> {
        use std::io::{Read, Seek, SeekFrom};
        let mut file = File::open(path)?;
        let file_bytes = file.metadata()?.len();
        let mut head = vec![0u8; HEADER_LEN];
        file.read_exact(&mut head).map_err(|_| {
            anyhow::anyhow!(
                "corrupt archive: file shorter than the \
                 {HEADER_LEN}-byte header ({file_bytes} bytes)"
            )
        })?;
        let h = parse_header(&head)?;
        anyhow::ensure!(
            (HEADER_LEN as u64)
                .checked_add(h.meta_len)
                .is_some_and(|end| end <= file_bytes)
                && h.index_off
                    .checked_add(h.index_len)
                    .is_some_and(|end| end == file_bytes),
            "corrupt archive: section table out of bounds"
        );
        let mut meta = vec![0u8; h.meta_len as usize];
        file.read_exact(&mut meta)?;
        let (manifest, _, _) = parse_meta(&meta)?;
        file.seek(SeekFrom::Start(h.index_off))?;
        let mut index = vec![0u8; h.index_len as usize];
        file.read_exact(&mut index)?;
        let entries = parse_index(&index, h.dispatch_count)?;

        let mut blocks = 0u64;
        let mut records = 0u64;
        let mut addr_words = 0u64;
        for (_, bs) in &entries {
            blocks += bs.len() as u64;
            for b in bs {
                records += b.n_records as u64;
                addr_words += b.n_addr as u64;
            }
        }
        Ok(ArchiveInfo {
            path: path.to_path_buf(),
            file_bytes,
            version: h.version,
            case_key: h.case_key,
            base_group_size: h.base_group_size,
            manifest,
            dispatches: entries.len(),
            blocks,
            records,
            addr_words,
        })
    }

    /// Scan every `.rtrc` file in `dir`, sorted by file name.
    pub fn scan_dir(dir: &Path) -> anyhow::Result<Vec<ArchiveInfo>> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| {
                anyhow::anyhow!(
                    "read archive dir {}: {e}",
                    dir.display()
                )
            })?
            .filter_map(|entry| entry.ok().map(|e| e.path()))
            .filter(|p| {
                p.extension().and_then(|x| x.to_str())
                    == Some(EXTENSION)
            })
            .collect();
        paths.sort();
        paths.iter().map(|p| ArchiveInfo::scan(p)).collect()
    }

    /// Case name parsed out of the manifest line (best effort — the
    /// manifest is `case name=<x> ...`).
    pub fn case_name(&self) -> &str {
        self.manifest
            .split_whitespace()
            .find_map(|kv| kv.strip_prefix("name="))
            .unwrap_or("?")
    }
}
