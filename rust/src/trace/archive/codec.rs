//! Per-column codecs for archive format v2: delta + LEB128 varint for
//! the wide integer columns, run-length encoding for the byte columns.
//!
//! Every codec here transforms a column's **raw byte image** (exactly
//! the bytes format v1 stores: little-endian fixed-width elements) to
//! and from an encoded byte stream. Decoding therefore reconstructs
//! the v1 section verbatim, which is what lets the reader run one set
//! of structural validations — enum codes, tape/stream agreement,
//! payload bounds — over raw-mapped and decoded columns alike.
//!
//! Codecs are exact, not lossy, for *any* input (proven by the
//! property tests below over random and adversarial columns):
//!
//! * **Delta+varint** ([`Encoding::DeltaVarint`]): each element is
//!   replaced by the zigzagged wrapping difference from its
//!   predecessor (the first element's predecessor is 0), written as an
//!   LEB128 varint. Monotone-ish streams — compacted lane addresses,
//!   `acc_off` arena cursors, dense group ids — become one- or
//!   two-byte deltas instead of 8 (or 4) raw bytes; a pathological
//!   stream degrades to ≤ 10 bytes per u64 element but still round
//!   trips (the writer's `auto` heuristic falls back to raw when
//!   encoding doesn't pay).
//! * **RLE** ([`Encoding::Rle`]): `(varint run length ≥ 1, value
//!   byte)` pairs. The low-cardinality byte columns (`tags`,
//!   `inst_class`, `acc_kind`, `acc_bpl`, `acc_len`) run in long
//!   stretches; alternating bytes degrade to 2 bytes per element —
//!   again the heuristic's problem, not correctness's.
//!
//! Decoding is fully bounds- and shape-checked: a truncated stream, a
//! varint running past 10 bytes (u64 overflow), a zero-length run, or
//! an element count that disagrees with the index are all clean
//! `anyhow` errors — corrupt archives can never panic the reader (the
//! same contract every other layer of the format keeps).
//!
//! **Decode is batched.** The hot path (`delta_varint_decode` on the
//! addr-dominated u64/u32 columns) decodes varints in chunks of
//! [`DECODE_LANES`] with a **single bounds check per chunk** — one
//! `remaining ≥ LANES × 10` guard licenses unchecked byte reads for
//! all eight varints — then applies the zigzag-delta prefix sum as an
//! unrolled fixed-width kernel and emits the raw little-endian image
//! 64 bytes at a time. The last few elements (and any stream too
//! short for a full chunk guard) fall back to the fully checked
//! scalar loop, so every corrupt-stream error keeps its exact scalar
//! wording and byte position. RLE expansion was already run-at-a-time
//! (`Vec::resize` = one memset per run); its run-length varints now
//! take the same single-check fast path. The pre-batching scalar
//! decoders survive verbatim in [`bench_hooks`] as the differential
//! oracle and the `codec_decode_batched_vs_scalar` bench baseline.

/// Wire encoding of one stored column section (the per-section
/// `encoding` byte in the v2 block index).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    /// The v1 byte image, mapped zero-copy at replay.
    Raw,
    /// Zigzag deltas of fixed-width elements, LEB128 varints.
    DeltaVarint,
    /// `(varint run length, byte)` pairs.
    Rle,
}

impl Encoding {
    pub fn to_u8(self) -> u8 {
        match self {
            Encoding::Raw => 0,
            Encoding::DeltaVarint => 1,
            Encoding::Rle => 2,
        }
    }

    pub fn from_u8(b: u8) -> Option<Encoding> {
        match b {
            0 => Some(Encoding::Raw),
            1 => Some(Encoding::DeltaVarint),
            2 => Some(Encoding::Rle),
            _ => None,
        }
    }

    /// Short human label for `trace-info`.
    pub fn label(self) -> &'static str {
        match self {
            Encoding::Raw => "raw",
            Encoding::DeltaVarint => "dv",
            Encoding::Rle => "rle",
        }
    }
}

/// Element width of a fixed-width column, for [`Encoding::DeltaVarint`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemWidth {
    U8,
    U32,
    U64,
}

impl ElemWidth {
    pub fn bytes(self) -> usize {
        match self {
            ElemWidth::U8 => 1,
            ElemWidth::U32 => 4,
            ElemWidth::U64 => 8,
        }
    }

    /// The codec applicable to columns of this width (`None` for the
    /// byte columns which use RLE instead).
    pub fn codec(self) -> Encoding {
        match self {
            ElemWidth::U8 => Encoding::Rle,
            ElemWidth::U32 | ElemWidth::U64 => Encoding::DeltaVarint,
        }
    }
}

// ------------------------------------------------------------ varint

/// Append `v` as an LEB128 varint (1–10 bytes).
fn varint_push(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Longest legal LEB128 encoding of a u64: 10 bytes (9 × 7 payload
/// bits + the top bit in the 10th byte).
const VARINT_MAX: usize = 10;

/// Elements per batched-decode chunk (the unroll width of the
/// zigzag-delta prefix-sum kernel).
const DECODE_LANES: usize = 8;

/// Read one LEB128 varint from `buf` at `*pos`, advancing it. Errors
/// on truncation and on encodings that overflow a u64.
fn varint_read(buf: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).ok_or_else(|| {
            anyhow::anyhow!(
                "corrupt section: varint truncated at byte {}",
                *pos
            )
        })?;
        *pos += 1;
        let payload = (b & 0x7f) as u64;
        // the 10th byte may only carry the top bit of a u64
        anyhow::ensure!(
            shift < 64 && (shift != 63 || payload <= 1),
            "corrupt section: varint overflows u64"
        );
        v |= payload << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Fast-path varint read: the caller has already checked that at
/// least [`VARINT_MAX`] bytes remain at `*pos`, so the byte reads
/// here carry no per-byte bounds checks. Bit-identical to
/// [`varint_read`], including every error message and the reported
/// truncation position.
#[inline]
fn varint_read_within(buf: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    let p = *pos;
    debug_assert!(buf.len() - p >= VARINT_MAX);
    let mut v: u64 = 0;
    for k in 0..VARINT_MAX {
        // SAFETY: p + VARINT_MAX <= buf.len() (caller's chunk guard)
        // and k < VARINT_MAX.
        let b = unsafe { *buf.get_unchecked(p + k) };
        let payload = (b & 0x7f) as u64;
        // the 10th byte (shift 63) may only carry the top bit
        anyhow::ensure!(
            k != VARINT_MAX - 1 || payload <= 1,
            "corrupt section: varint overflows u64"
        );
        v |= payload << (7 * k as u32);
        if b & 0x80 == 0 {
            *pos = p + k + 1;
            return Ok(v);
        }
    }
    // ten continuation bytes: the scalar reader would fetch an 11th —
    // overflow if one exists, truncation at its position otherwise
    if p + VARINT_MAX < buf.len() {
        anyhow::bail!("corrupt section: varint overflows u64");
    }
    anyhow::bail!(
        "corrupt section: varint truncated at byte {}",
        p + VARINT_MAX
    )
}

/// Zigzag map: interleave negative deltas with positive ones so small
/// magnitudes of either sign stay small varints.
#[inline]
fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

// ----------------------------------------------------- delta varint

/// Little-endian element at index `i` of a raw column image.
#[inline]
fn elem_at(raw: &[u8], i: usize, width: ElemWidth) -> u64 {
    match width {
        ElemWidth::U8 => raw[i] as u64,
        ElemWidth::U32 => u32::from_le_bytes(
            raw[i * 4..i * 4 + 4].try_into().expect("4 bytes"),
        ) as u64,
        ElemWidth::U64 => u64::from_le_bytes(
            raw[i * 8..i * 8 + 8].try_into().expect("8 bytes"),
        ),
    }
}

/// Encode a raw fixed-width column image as zigzagged wrapping deltas
/// in LEB128 varints. `raw.len()` must be a multiple of the element
/// width (the writer always passes whole columns).
pub fn delta_varint_encode(
    raw: &[u8],
    width: ElemWidth,
    out: &mut Vec<u8>,
) {
    out.clear();
    let w = width.bytes();
    debug_assert_eq!(raw.len() % w, 0);
    let n = raw.len() / w;
    out.reserve(n * 2);
    let mut prev = 0u64;
    for i in 0..n {
        let cur = elem_at(raw, i, width);
        // wrapping difference: exact for any pair of u64s (and, since
        // u32 elements are ≤ u32::MAX, exact in i64 for u32 columns)
        let delta = cur.wrapping_sub(prev) as i64;
        varint_push(out, zigzag(delta));
        prev = cur;
    }
}

/// Decode a [`delta_varint_encode`] stream back into the raw byte
/// image of `n_elems` elements, appending to `out`. Errors on
/// truncation, varint overflow, trailing bytes, and (for u32 columns)
/// decoded values outside the element range.
///
/// The u64/u32 paths are batched: [`DECODE_LANES`] varints per chunk
/// under one bounds check, an unrolled zigzag-delta prefix sum, and
/// one chunk-sized byte-image append. Element order, output bytes and
/// every error are identical to the scalar reference
/// ([`bench_hooks::delta_varint_decode_scalar`], property-proven
/// below).
pub fn delta_varint_decode(
    enc: &[u8],
    n_elems: usize,
    width: ElemWidth,
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    let mut pos = 0usize;
    let mut prev = 0u64;
    out.reserve(n_elems * width.bytes());
    let mut i = 0usize;
    match width {
        ElemWidth::U64 => {
            // a full chunk's worst case is LANES maximal varints;
            // one guard licenses unchecked reads for all of them
            while i + DECODE_LANES <= n_elems
                && enc.len() - pos >= DECODE_LANES * VARINT_MAX
            {
                let mut zz = [0u64; DECODE_LANES];
                for z in zz.iter_mut() {
                    *z = varint_read_within(enc, &mut pos)?;
                }
                // unrolled zigzag + wrapping prefix sum
                let mut bytes = [0u8; DECODE_LANES * 8];
                let mut acc = prev;
                for k in 0..DECODE_LANES {
                    acc = acc.wrapping_add(unzigzag(zz[k]) as u64);
                    bytes[k * 8..k * 8 + 8]
                        .copy_from_slice(&acc.to_le_bytes());
                }
                prev = acc;
                out.extend_from_slice(&bytes);
                i += DECODE_LANES;
            }
        }
        ElemWidth::U32 => {
            while i + DECODE_LANES <= n_elems
                && enc.len() - pos >= DECODE_LANES * VARINT_MAX
            {
                let mut bytes = [0u8; DECODE_LANES * 4];
                let mut acc = prev;
                for k in 0..DECODE_LANES {
                    let z = varint_read_within(enc, &mut pos)?;
                    acc = acc.wrapping_add(unzigzag(z) as u64);
                    anyhow::ensure!(
                        acc <= u32::MAX as u64,
                        "corrupt section: element {} decodes to \
                         {acc}, outside u32 range",
                        i + k
                    );
                    bytes[k * 4..k * 4 + 4]
                        .copy_from_slice(&(acc as u32).to_le_bytes());
                }
                prev = acc;
                out.extend_from_slice(&bytes);
                i += DECODE_LANES;
            }
        }
        // byte columns never use DeltaVarint in practice (see
        // `decode`); the checked tail below handles them whole
        ElemWidth::U8 => {}
    }
    // fully checked scalar tail: the last partial chunk, plus any
    // stream too short to clear the chunk guard
    while i < n_elems {
        let delta = unzigzag(varint_read(enc, &mut pos)?);
        let cur = prev.wrapping_add(delta as u64);
        match width {
            ElemWidth::U8 => {
                anyhow::ensure!(
                    cur <= u8::MAX as u64,
                    "corrupt section: element {i} decodes to {cur}, \
                     outside u8 range"
                );
                out.push(cur as u8);
            }
            ElemWidth::U32 => {
                anyhow::ensure!(
                    cur <= u32::MAX as u64,
                    "corrupt section: element {i} decodes to {cur}, \
                     outside u32 range"
                );
                out.extend_from_slice(&(cur as u32).to_le_bytes());
            }
            ElemWidth::U64 => {
                out.extend_from_slice(&cur.to_le_bytes());
            }
        }
        prev = cur;
        i += 1;
    }
    anyhow::ensure!(
        pos == enc.len(),
        "corrupt section: {} trailing byte(s) after {n_elems} \
         delta-varint elements",
        enc.len() - pos
    );
    Ok(())
}

// -------------------------------------------------------------- rle

/// Encode a byte column as `(varint run length, value)` pairs.
pub fn rle_encode(raw: &[u8], out: &mut Vec<u8>) {
    out.clear();
    let mut i = 0usize;
    while i < raw.len() {
        let v = raw[i];
        let mut j = i + 1;
        while j < raw.len() && raw[j] == v {
            j += 1;
        }
        varint_push(out, (j - i) as u64);
        out.push(v);
        i = j;
    }
}

/// Decode an [`rle_encode`] stream back into `n_elems` bytes,
/// appending to `out`. Errors on truncation, zero-length runs, runs
/// overshooting the element count, and trailing bytes.
///
/// Expansion is run-at-a-time (`Vec::resize` — one memset per run);
/// the run-length varints take the single-check fast path whenever a
/// full [`VARINT_MAX`] window remains.
pub fn rle_decode(
    enc: &[u8],
    n_elems: usize,
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    let mut pos = 0usize;
    let mut produced = 0usize;
    out.reserve(n_elems);
    while produced < n_elems {
        let run = if enc.len() - pos >= VARINT_MAX {
            varint_read_within(enc, &mut pos)?
        } else {
            varint_read(enc, &mut pos)?
        };
        anyhow::ensure!(
            run >= 1 && run <= (n_elems - produced) as u64,
            "corrupt section: RLE run of {run} at element {produced} \
             (of {n_elems})"
        );
        let v = *enc.get(pos).ok_or_else(|| {
            anyhow::anyhow!(
                "corrupt section: RLE value byte truncated"
            )
        })?;
        pos += 1;
        out.resize(out.len() + run as usize, v);
        produced += run as usize;
    }
    anyhow::ensure!(
        pos == enc.len(),
        "corrupt section: {} trailing byte(s) after {n_elems} RLE \
         elements",
        enc.len() - pos
    );
    Ok(())
}

// -------------------------------------------------------- dispatch

/// Encode `raw` with the codec native to `width` (see
/// [`ElemWidth::codec`]), into `out`. Returns the encoding used.
pub fn encode(raw: &[u8], width: ElemWidth, out: &mut Vec<u8>) -> Encoding {
    match width.codec() {
        Encoding::Rle => {
            rle_encode(raw, out);
            Encoding::Rle
        }
        _ => {
            delta_varint_encode(raw, width, out);
            Encoding::DeltaVarint
        }
    }
}

/// Decode `enc` (stored under `encoding`) back into the raw byte image
/// of `n_elems` elements of `width`, appending to `out`.
/// [`Encoding::Raw`] is not a decode — callers replay raw sections in
/// place — so passing it here is a corrupt-index error, as is an
/// encoding/width pairing the writer never produces.
pub fn decode(
    enc: &[u8],
    encoding: Encoding,
    n_elems: usize,
    width: ElemWidth,
    out: &mut Vec<u8>,
) -> anyhow::Result<()> {
    if crate::fault::should_fail("codec.decode") {
        anyhow::bail!(
            "injected fault at codec.decode (simulated decode-arena \
             exhaustion)"
        );
    }
    match (encoding, width) {
        (Encoding::Rle, ElemWidth::U8) => {
            rle_decode(enc, n_elems, out)
        }
        (Encoding::DeltaVarint, ElemWidth::U32 | ElemWidth::U64) => {
            delta_varint_decode(enc, n_elems, width, out)
        }
        _ => anyhow::bail!(
            "corrupt archive: section encoding {encoding:?} is not \
             valid for {width:?} elements"
        ),
    }
}

// ------------------------------------------------------ bench hooks

/// Scalar reference decoders: the pre-batching byte-at-a-time
/// implementations, kept verbatim as (a) the differential oracle the
/// property tests pit the batched kernels against and (b) the
/// baseline side of the `codec_decode_batched_vs_scalar` hotpath
/// bench. Not part of the archive API.
#[doc(hidden)]
pub mod bench_hooks {
    use super::{unzigzag, varint_read, ElemWidth, Encoding};

    /// Scalar [`super::delta_varint_decode`]: one checked varint and
    /// one element append per iteration.
    pub fn delta_varint_decode_scalar(
        enc: &[u8],
        n_elems: usize,
        width: ElemWidth,
        out: &mut Vec<u8>,
    ) -> anyhow::Result<()> {
        let mut pos = 0usize;
        let mut prev = 0u64;
        for i in 0..n_elems {
            let delta = unzigzag(varint_read(enc, &mut pos)?);
            let cur = prev.wrapping_add(delta as u64);
            match width {
                ElemWidth::U8 => {
                    anyhow::ensure!(
                        cur <= u8::MAX as u64,
                        "corrupt section: element {i} decodes to \
                         {cur}, outside u8 range"
                    );
                    out.push(cur as u8);
                }
                ElemWidth::U32 => {
                    anyhow::ensure!(
                        cur <= u32::MAX as u64,
                        "corrupt section: element {i} decodes to \
                         {cur}, outside u32 range"
                    );
                    out.extend_from_slice(
                        &(cur as u32).to_le_bytes(),
                    );
                }
                ElemWidth::U64 => {
                    out.extend_from_slice(&cur.to_le_bytes());
                }
            }
            prev = cur;
        }
        anyhow::ensure!(
            pos == enc.len(),
            "corrupt section: {} trailing byte(s) after {n_elems} \
             delta-varint elements",
            enc.len() - pos
        );
        Ok(())
    }

    /// Scalar [`super::rle_decode`]: every run-length varint fully
    /// bounds-checked byte by byte.
    pub fn rle_decode_scalar(
        enc: &[u8],
        n_elems: usize,
        out: &mut Vec<u8>,
    ) -> anyhow::Result<()> {
        let mut pos = 0usize;
        let mut produced = 0usize;
        while produced < n_elems {
            let run = varint_read(enc, &mut pos)?;
            anyhow::ensure!(
                run >= 1 && run <= (n_elems - produced) as u64,
                "corrupt section: RLE run of {run} at element \
                 {produced} (of {n_elems})"
            );
            let v = *enc.get(pos).ok_or_else(|| {
                anyhow::anyhow!(
                    "corrupt section: RLE value byte truncated"
                )
            })?;
            pos += 1;
            out.resize(out.len() + run as usize, v);
            produced += run as usize;
        }
        anyhow::ensure!(
            pos == enc.len(),
            "corrupt section: {} trailing byte(s) after {n_elems} \
             RLE elements",
            enc.len() - pos
        );
        Ok(())
    }

    /// Scalar [`super::decode`]: same valid-pair dispatch, scalar
    /// kernels.
    pub fn decode_scalar(
        enc: &[u8],
        encoding: Encoding,
        n_elems: usize,
        width: ElemWidth,
        out: &mut Vec<u8>,
    ) -> anyhow::Result<()> {
        match (encoding, width) {
            (Encoding::Rle, ElemWidth::U8) => {
                rle_decode_scalar(enc, n_elems, out)
            }
            (
                Encoding::DeltaVarint,
                ElemWidth::U32 | ElemWidth::U64,
            ) => delta_varint_decode_scalar(enc, n_elems, width, out),
            _ => anyhow::bail!(
                "corrupt archive: section encoding {encoding:?} is \
                 not valid for {width:?} elements"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn raw_u64(vals: &[u64]) -> Vec<u8> {
        let mut out = Vec::with_capacity(vals.len() * 8);
        for v in vals {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    fn raw_u32(vals: &[u32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    fn round_trip(raw: &[u8], width: ElemWidth) -> Vec<u8> {
        let mut enc = Vec::new();
        let encoding = encode(raw, width, &mut enc);
        let mut dec = Vec::new();
        decode(
            &enc,
            encoding,
            raw.len() / width.bytes(),
            width,
            &mut dec,
        )
        .unwrap();
        assert_eq!(dec, raw, "round trip must be exact");
        enc
    }

    #[test]
    fn varint_round_trips_boundary_values() {
        for v in [
            0u64,
            1,
            0x7f,
            0x80,
            0x3fff,
            0x4000,
            u32::MAX as u64,
            u64::MAX - 1,
            u64::MAX,
        ] {
            let mut buf = Vec::new();
            varint_push(&mut buf, v);
            assert!(buf.len() <= 10);
            let mut pos = 0;
            assert_eq!(varint_read(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn varint_rejects_truncation_and_overflow() {
        // truncated continuation
        let mut pos = 0;
        let err = varint_read(&[0x80], &mut pos)
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "{err}");
        // 11-byte encoding overflows u64
        let mut pos = 0;
        let buf = [0x80u8; 11];
        let err =
            varint_read(&buf, &mut pos).unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");
        // 10th byte carrying more than the top bit overflows too
        let mut buf = vec![0xffu8; 9];
        buf.push(0x02);
        let mut pos = 0;
        let err =
            varint_read(&buf, &mut pos).unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");
    }

    #[test]
    fn delta_varint_compresses_strided_addresses() {
        // a compacted-lane address column: stride-12 AoS reads, the
        // archive's dominant shape — one varint byte per delta
        let addrs: Vec<u64> =
            (0..4096u64).map(|i| 0x4000_0000 + i * 12).collect();
        let raw = raw_u64(&addrs);
        let enc = round_trip(&raw, ElemWidth::U64);
        assert!(
            enc.len() * 4 <= raw.len(),
            "strided addrs must shrink ≥4x ({} -> {})",
            raw.len(),
            enc.len()
        );
    }

    #[test]
    fn delta_varint_round_trips_adversarial_u64_columns() {
        let cases: Vec<Vec<u64>> = vec![
            vec![],
            vec![0],
            vec![u64::MAX],
            vec![u64::MAX, 0, u64::MAX, 1, u64::MAX / 2],
            vec![0, u64::MAX, 0, u64::MAX],
            (0..257u64).rev().collect(),
            vec![0x8000_0000_0000_0000; 31],
        ];
        for vals in cases {
            round_trip(&raw_u64(&vals), ElemWidth::U64);
        }
    }

    #[test]
    fn delta_varint_round_trips_random_columns_property() {
        let mut rng = Xoshiro256::seed_from_u64(0xC0DEC);
        for case in 0..64 {
            let n = rng.below(300) as usize;
            let vals: Vec<u64> = (0..n)
                .map(|_| match rng.below(4) {
                    // mixture: raw entropy, small walks, clustered
                    0 => rng.next_u64(),
                    1 => rng.below(1 << 20),
                    2 => 0x4000_0000 + rng.below(1 << 12) * 4,
                    _ => u64::MAX - rng.below(1 << 8),
                })
                .collect();
            round_trip(&raw_u64(&vals), ElemWidth::U64);
            // same property for u32 columns
            let vals32: Vec<u32> =
                vals.iter().map(|v| *v as u32).collect();
            round_trip(&raw_u32(&vals32), ElemWidth::U32);
            let _ = case;
        }
    }

    #[test]
    fn u32_decode_rejects_out_of_range_values() {
        // encode a u64 column, then decode it claiming u32 elements:
        // the first out-of-range element must error cleanly
        let raw = raw_u64(&[u32::MAX as u64 + 1]);
        let mut enc = Vec::new();
        delta_varint_encode(&raw, ElemWidth::U64, &mut enc);
        let mut out = Vec::new();
        let err =
            delta_varint_decode(&enc, 1, ElemWidth::U32, &mut out)
                .unwrap_err()
                .to_string();
        assert!(err.contains("outside u32 range"), "{err}");
    }

    #[test]
    fn delta_varint_rejects_wrong_element_counts() {
        let raw = raw_u64(&[5, 6, 7]);
        let mut enc = Vec::new();
        delta_varint_encode(&raw, ElemWidth::U64, &mut enc);
        let mut out = Vec::new();
        // too few claimed elements: trailing bytes
        let err =
            delta_varint_decode(&enc, 2, ElemWidth::U64, &mut out)
                .unwrap_err()
                .to_string();
        assert!(err.contains("trailing"), "{err}");
        // too many claimed elements: truncation
        let mut out = Vec::new();
        let err =
            delta_varint_decode(&enc, 4, ElemWidth::U64, &mut out)
                .unwrap_err()
                .to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn rle_compresses_low_cardinality_columns() {
        // an acc_len column: 64 active lanes everywhere
        let raw = vec![64u8; 4096];
        let enc = round_trip(&raw, ElemWidth::U8);
        assert!(enc.len() <= 4, "{} bytes", enc.len());
    }

    #[test]
    fn rle_round_trips_adversarial_byte_columns() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![0],
            vec![255; 1000],
            (0..=255u8).collect(),                   // no runs at all
            (0..512).map(|i| (i % 2) as u8).collect(), // worst case
            vec![1, 1, 2, 2, 2, 0, 0, 0, 0, 7],
        ];
        for raw in cases {
            round_trip(&raw, ElemWidth::U8);
        }
    }

    #[test]
    fn rle_round_trips_random_columns_property() {
        let mut rng = Xoshiro256::seed_from_u64(0x51E);
        for _ in 0..64 {
            let n = rng.below(400) as usize;
            let mut raw = Vec::with_capacity(n);
            let mut v = 0u8;
            for _ in 0..n {
                if rng.below(3) == 0 {
                    v = rng.below(5) as u8;
                }
                raw.push(v);
            }
            round_trip(&raw, ElemWidth::U8);
        }
    }

    #[test]
    fn rle_rejects_malformed_streams() {
        let mut out = Vec::new();
        // zero-length run
        let err = rle_decode(&[0x00, 0x07], 1, &mut out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("RLE run"), "{err}");
        // run overshooting the element count
        let mut out = Vec::new();
        let err = rle_decode(&[0x05, 0x07], 3, &mut out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("RLE run"), "{err}");
        // missing value byte
        let mut out = Vec::new();
        let err = rle_decode(&[0x02], 2, &mut out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("truncated"), "{err}");
        // trailing bytes after the final run
        let mut out = Vec::new();
        let err = rle_decode(&[0x02, 0x07, 0x01], 2, &mut out)
            .unwrap_err()
            .to_string();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn encoding_wire_bytes_are_pinned() {
        // the encoding byte is part of the on-disk format — pin it
        for (e, b) in [
            (Encoding::Raw, 0u8),
            (Encoding::DeltaVarint, 1),
            (Encoding::Rle, 2),
        ] {
            assert_eq!(e.to_u8(), b);
            assert_eq!(Encoding::from_u8(b), Some(e));
        }
        assert_eq!(Encoding::from_u8(3), None);
    }

    #[test]
    fn batched_decode_matches_scalar_on_random_columns() {
        // differential property: the chunked/unrolled decoders and
        // the scalar references must agree byte-for-byte, at sizes
        // straddling every chunk boundary
        let mut rng = Xoshiro256::seed_from_u64(0xBA7C4);
        let sizes =
            [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 64, 65, 500, 4096];
        for &n in &sizes {
            let vals: Vec<u64> = (0..n)
                .map(|_| match rng.below(4) {
                    0 => rng.next_u64(),
                    1 => rng.below(1 << 20),
                    2 => 0x4000_0000 + rng.below(1 << 12) * 4,
                    _ => u64::MAX - rng.below(1 << 8),
                })
                .collect();
            let raw = raw_u64(&vals);
            let mut enc = Vec::new();
            delta_varint_encode(&raw, ElemWidth::U64, &mut enc);
            let (mut fast, mut slow) = (Vec::new(), Vec::new());
            delta_varint_decode(&enc, n, ElemWidth::U64, &mut fast)
                .unwrap();
            bench_hooks::delta_varint_decode_scalar(
                &enc,
                n,
                ElemWidth::U64,
                &mut slow,
            )
            .unwrap();
            assert_eq!(fast, slow, "u64 n={n}");

            let vals32: Vec<u32> =
                vals.iter().map(|v| *v as u32).collect();
            let raw32 = raw_u32(&vals32);
            let mut enc32 = Vec::new();
            delta_varint_encode(&raw32, ElemWidth::U32, &mut enc32);
            let (mut fast, mut slow) = (Vec::new(), Vec::new());
            delta_varint_decode(&enc32, n, ElemWidth::U32, &mut fast)
                .unwrap();
            bench_hooks::delta_varint_decode_scalar(
                &enc32,
                n,
                ElemWidth::U32,
                &mut slow,
            )
            .unwrap();
            assert_eq!(fast, slow, "u32 n={n}");

            let bytes: Vec<u8> =
                vals.iter().map(|v| (*v % 5) as u8).collect();
            let mut encb = Vec::new();
            rle_encode(&bytes, &mut encb);
            let (mut fast, mut slow) = (Vec::new(), Vec::new());
            rle_decode(&encb, n, &mut fast).unwrap();
            bench_hooks::rle_decode_scalar(&encb, n, &mut slow)
                .unwrap();
            assert_eq!(fast, slow, "rle n={n}");
        }
    }

    #[test]
    fn batched_decode_matches_scalar_on_corrupt_streams() {
        // truncate a valid stream at every byte position: the batched
        // decoder must fail exactly where and how the scalar one does
        let vals: Vec<u64> = (0..64u64)
            .map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        let raw = raw_u64(&vals);
        let mut enc = Vec::new();
        delta_varint_encode(&raw, ElemWidth::U64, &mut enc);
        for cut in 0..enc.len() {
            let (mut fast, mut slow) = (Vec::new(), Vec::new());
            let ef = delta_varint_decode(
                &enc[..cut],
                vals.len(),
                ElemWidth::U64,
                &mut fast,
            )
            .unwrap_err()
            .to_string();
            let es = bench_hooks::delta_varint_decode_scalar(
                &enc[..cut],
                vals.len(),
                ElemWidth::U64,
                &mut slow,
            )
            .unwrap_err()
            .to_string();
            assert_eq!(ef, es, "cut={cut}");
        }
        // and a mid-chunk u32 range overflow names the same element
        let raw = raw_u64(&[1, 2, 3, 4, 5, 6, u32::MAX as u64 + 9, 8]);
        let mut enc = Vec::new();
        delta_varint_encode(&raw, ElemWidth::U64, &mut enc);
        // pad so the chunk guard passes and the fast path is taken
        let mut padded = enc.clone();
        padded.extend_from_slice(&[0u8; 80]);
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        let ef = delta_varint_decode(
            &padded,
            8,
            ElemWidth::U32,
            &mut fast,
        )
        .unwrap_err()
        .to_string();
        let es = bench_hooks::delta_varint_decode_scalar(
            &enc,
            8,
            ElemWidth::U32,
            &mut slow,
        )
        .unwrap_err()
        .to_string();
        assert_eq!(ef, es);
        assert!(ef.contains("element 6"), "{ef}");
    }

    #[test]
    fn batched_decode_appends_like_scalar() {
        // decode appends — pre-existing bytes must survive
        let raw = raw_u64(&(0..32u64).collect::<Vec<_>>());
        let mut enc = Vec::new();
        delta_varint_encode(&raw, ElemWidth::U64, &mut enc);
        let mut out = vec![0xAB, 0xCD];
        delta_varint_decode(&enc, 32, ElemWidth::U64, &mut out)
            .unwrap();
        assert_eq!(&out[..2], &[0xAB, 0xCD]);
        assert_eq!(&out[2..], &raw[..]);
    }

    #[test]
    fn mismatched_encoding_width_pairs_are_errors() {
        let mut out = Vec::new();
        assert!(decode(&[], Encoding::Rle, 0, ElemWidth::U64, &mut out)
            .is_err());
        assert!(decode(
            &[],
            Encoding::DeltaVarint,
            0,
            ElemWidth::U8,
            &mut out
        )
        .is_err());
        assert!(
            decode(&[], Encoding::Raw, 0, ElemWidth::U64, &mut out)
                .is_err()
        );
    }
}
