//! On-disk layout constants, checksums and primitive codecs for the
//! trace archive (see `docs/trace-format.md` for the authoritative
//! layout specification).
//!
//! Everything in a `.rtrc` file is **little-endian** and byte-packed;
//! multi-byte *column sections* are additionally 8-byte aligned so the
//! reader can expose them as `&[u64]`/`&[u32]` slices straight out of
//! the mapping. The format never stores Rust enum discriminants — each
//! enum has an explicit wire encoding pinned by tests here, and the
//! reader validates every coded byte before any zero-copy replay
//! begins, so decoding can never panic on a corrupt file.

use crate::arch::InstClass;
use crate::trace::block::Tag;
use crate::trace::MemKind;

/// File magic: identifies a rocline trace archive, any version.
pub const MAGIC: [u8; 8] = *b"RLNTRACE";

/// Current format version. Bump whenever the layout, the column set,
/// or any wire encoding (including [`InstClass::ALL`] order) changes.
///
/// Version 2 added per-section column compression: each block-index
/// entry carries an `encoding` byte and a stored byte length per
/// column (see [`super::codec`] and `docs/trace-format.md`). The
/// writer emits v2; the reader accepts
/// [`MIN_FORMAT_VERSION`]..=[`FORMAT_VERSION`], with v1 files read as
/// all-raw (their index stores no encoding fields).
pub const FORMAT_VERSION: u32 = 2;

/// Oldest format version the reader still accepts (v1 archives remain
/// readable; they simply predate per-section encodings).
pub const MIN_FORMAT_VERSION: u32 = 1;

/// Endianness canary, written little-endian. A big-endian writer would
/// produce the byte-swapped value, which the reader rejects with a
/// dedicated message instead of a checksum mismatch.
pub const ENDIAN_TAG: u32 = 0x0102_0304;

/// [`ENDIAN_TAG`] as read on a machine of the opposite endianness.
pub const ENDIAN_TAG_SWAPPED: u32 = 0x0403_0201;

/// Fixed header size; the meta section starts right after it.
pub const HEADER_LEN: usize = 64;

/// File extension for case archives.
pub const EXTENSION: &str = "rtrc";

/// Number of column sections per block (wire order: tags, group_ids,
/// inst_class, inst_count, acc_kind, acc_bpl, acc_off, acc_len, addrs).
pub const COLUMNS: usize = 9;

/// Element width of each column, by wire position — the single table
/// the writer's codec selection and the reader's length/decode logic
/// both consult, so they cannot drift.
pub const COLUMN_WIDTHS: [super::codec::ElemWidth; COLUMNS] = [
    super::codec::ElemWidth::U8,  // tags
    super::codec::ElemWidth::U64, // group_ids
    super::codec::ElemWidth::U8,  // inst_class
    super::codec::ElemWidth::U64, // inst_count
    super::codec::ElemWidth::U8,  // acc_kind
    super::codec::ElemWidth::U8,  // acc_bpl
    super::codec::ElemWidth::U32, // acc_off
    super::codec::ElemWidth::U8,  // acc_len
    super::codec::ElemWidth::U64, // addrs
];

/// Short column names, by wire position (for `trace-info` reporting).
pub const COLUMN_NAMES: [&str; COLUMNS] = [
    "tags",
    "group_ids",
    "inst_class",
    "inst_count",
    "acc_kind",
    "acc_bpl",
    "acc_off",
    "acc_len",
    "addrs",
];

/// Bit mask with one bit set per wire column — a
/// [`super::reader::MappedBlock`] whose arena mask equals this
/// resolves **every** column from its decode arena and never touches
/// the mapped file, which is what the streaming tier's fully
/// arena-resident blocks rely on.
pub const ALL_COLUMNS_MASK: u16 = (1 << COLUMNS) - 1;

/// Section alignment: column offsets are multiples of this, which
/// (with a page-aligned mapping) makes `&[u64]` views sound.
pub const ALIGN: usize = 8;

/// Round `n` up to the next [`ALIGN`] boundary.
pub fn align_up(n: u64) -> u64 {
    n.div_ceil(ALIGN as u64) * ALIGN as u64
}

// ---------------------------------------------------------------- fnv

/// Incremental FNV-1a (64-bit) — the format's checksum. Not
/// cryptographic; it guards against truncation, bit rot and torn
/// writes, which is all an integrity check on a local cache needs.
#[derive(Debug, Clone, Copy)]
pub struct Fnv(u64);

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Default for Fnv {
    fn default() -> Self {
        Fnv(FNV_OFFSET)
    }
}

impl Fnv {
    pub fn new() -> Fnv {
        Fnv::default()
    }

    pub fn write(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }

    pub fn finish(self) -> u64 {
        self.0
    }
}

/// One-shot [`Fnv`] over a byte slice.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut f = Fnv::new();
    f.write(bytes);
    f.finish()
}

/// The content-addressed key of one recorded case: a pure function of
/// the case's manifest line (its full [`crate::pic::CaseConfig`]
/// rendering), the recording group size, the simulation seed, and the
/// format version. Any ingredient change re-keys the archive file, so
/// stale recordings are never replayed silently.
pub fn case_key(manifest: &str, base_group_size: u32, seed: u64) -> u64 {
    let mut f = Fnv::new();
    f.write(manifest.as_bytes());
    f.write(&base_group_size.to_le_bytes());
    f.write(&seed.to_le_bytes());
    f.write(&FORMAT_VERSION.to_le_bytes());
    f.finish()
}

/// File name of a case archive inside an archive directory.
pub fn archive_file_name(case_name: &str, key: u64) -> String {
    let stem: String = case_name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect();
    format!("{stem}-{key:016x}.{EXTENSION}")
}

// ------------------------------------------------------- enum codecs

/// Wire encoding of [`Tag`]: 0 = Inst, 1 = Mem, 2 = Lds. The enum is
/// `repr(u8)` with these exact discriminants (pinned by the round-trip
/// test below), which is what makes the reader's zero-copy `&[Tag]`
/// column view sound after open-time byte validation.
pub fn tag_to_u8(t: Tag) -> u8 {
    t as u8
}

pub fn tag_from_u8(b: u8) -> Option<Tag> {
    match b {
        0 => Some(Tag::Inst),
        1 => Some(Tag::Mem),
        2 => Some(Tag::Lds),
        _ => None,
    }
}

/// Wire encoding of [`MemKind`]: 0 = Read, 1 = Write, 2 = Atomic —
/// also the enum's `repr(u8)` discriminants (see [`tag_to_u8`]).
pub fn kind_to_u8(k: MemKind) -> u8 {
    k as u8
}

pub fn kind_from_u8(b: u8) -> Option<MemKind> {
    match b {
        0 => Some(MemKind::Read),
        1 => Some(MemKind::Write),
        2 => Some(MemKind::Atomic),
        _ => None,
    }
}

/// Wire encoding of [`InstClass`]: the index into [`InstClass::ALL`],
/// which is also the enum's `repr(u8)` discriminant. That order is
/// therefore part of the format — reordering or extending the enum
/// requires a [`FORMAT_VERSION`] bump (pinned by the
/// `inst_class_wire_encoding_is_stable` test below).
pub fn class_to_u8(c: InstClass) -> u8 {
    c as u8
}

pub fn class_from_u8(b: u8) -> Option<InstClass> {
    InstClass::ALL.get(b as usize).copied()
}

// ----------------------------------------------------- bounded reads

/// Bounds-checked little-endian cursor over a byte slice; every
/// overrun is a clean `anyhow` error (never a slicing panic), which is
/// what keeps corrupt-index handling panic-free.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.remaining(),
            "corrupt archive: truncated section (wanted {n} bytes at \
             offset {}, {} left)",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub fn u16(&mut self) -> anyhow::Result<u16> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        // incremental == one-shot
        let mut f = Fnv::new();
        f.write(b"foo");
        f.write(b"bar");
        assert_eq!(f.finish(), fnv1a(b"foobar"));
    }

    #[test]
    fn case_key_is_sensitive_to_every_ingredient() {
        let base = case_key("case name=x steps=4", 64, 7);
        assert_eq!(base, case_key("case name=x steps=4", 64, 7));
        assert_ne!(base, case_key("case name=x steps=5", 64, 7));
        assert_ne!(base, case_key("case name=x steps=4", 32, 7));
        assert_ne!(base, case_key("case name=x steps=4", 64, 8));
    }

    #[test]
    fn file_names_are_sanitized_and_keyed() {
        let n = archive_file_name("tiny a/b", 0xabc);
        assert_eq!(n, "tiny_a_b-0000000000000abc.rtrc");
    }

    #[test]
    fn tag_and_kind_round_trip() {
        for t in [Tag::Inst, Tag::Mem, Tag::Lds] {
            assert_eq!(tag_from_u8(tag_to_u8(t)), Some(t));
        }
        for k in [MemKind::Read, MemKind::Write, MemKind::Atomic] {
            assert_eq!(kind_from_u8(kind_to_u8(k)), Some(k));
        }
        assert_eq!(tag_from_u8(3), None);
        assert_eq!(kind_from_u8(9), None);
    }

    #[test]
    fn inst_class_wire_encoding_is_stable() {
        // the on-disk encoding is the index into InstClass::ALL;
        // changing this order is a format break (bump FORMAT_VERSION)
        let pinned = [
            (InstClass::ValuArith, 0u8),
            (InstClass::ValuSpecial, 1),
            (InstClass::Salu, 2),
            (InstClass::GlobalLoad, 3),
            (InstClass::GlobalStore, 4),
            (InstClass::GlobalAtomic, 5),
            (InstClass::LdsLoad, 6),
            (InstClass::LdsStore, 7),
            (InstClass::Branch, 8),
            (InstClass::Sync, 9),
            (InstClass::Misc, 10),
        ];
        assert_eq!(pinned.len(), InstClass::ALL.len());
        for (c, code) in pinned {
            assert_eq!(class_to_u8(c), code, "{c:?}");
            assert_eq!(class_from_u8(code), Some(c));
        }
        assert_eq!(class_from_u8(11), None);
    }

    #[test]
    fn cursor_bounds_errors_are_clean() {
        let mut c = Cursor::new(&[1, 0, 0, 0]);
        assert_eq!(c.u32().unwrap(), 1);
        let err = c.u64().unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn alignment_rounding() {
        assert_eq!(align_up(0), 0);
        assert_eq!(align_up(1), 8);
        assert_eq!(align_up(8), 8);
        assert_eq!(align_up(17), 24);
    }
}
