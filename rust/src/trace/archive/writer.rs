//! Archive writer: serialize one recorded case to a `.rtrc` file,
//! atomically.
//!
//! The writer streams (never holds the serialized file in memory):
//! header placeholder → meta → per-block column sections (8-aligned,
//! each checksummed over stored data *and* its trailing pad, so the
//! covered spans tile the whole data region) → index → patched header.
//! The file is assembled under a process-unique temporary name in the
//! destination directory and `rename(2)`d into place, so concurrent
//! shard processes spilling the same case race safely: whichever
//! rename lands last wins with a complete, identical file, and readers
//! only ever observe complete archives.
//!
//! **Format v2 compression** ([`Compress`]): each column section may
//! be stored raw (the v1 byte image, mapped zero-copy at replay) or
//! encoded by its column-native codec — delta+varint for the wide
//! integer columns, RLE for the byte columns (see [`super::codec`]).
//! Under [`Compress::Auto`] the writer encodes each section and keeps
//! whichever form is smaller, measured, never guessed — a section
//! whose encoding doesn't pay stays raw and keeps the zero-copy path.
//! The chosen encoding and the stored byte length land in the block
//! index, one entry per section.

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::codec::{self, Encoding};
use super::format::{
    align_up, case_key, class_to_u8, kind_to_u8, tag_to_u8, Fnv,
    COLUMNS, COLUMN_WIDTHS, ENDIAN_TAG, FORMAT_VERSION, HEADER_LEN,
    MAGIC, MIN_FORMAT_VERSION,
};
use crate::trace::block::BlockData;
use crate::trace::recorded::RecordedDispatch;

/// Per-section compression policy of one spill.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Compress {
    /// Format v2, every section raw (zero-copy replay everywhere).
    None,
    /// Format v2, per section: encode, keep the smaller form. The
    /// default — compression is taken only where it measurably pays.
    #[default]
    Auto,
    /// Format v2, every section encoded (even when larger) — the
    /// worst-case decode path, for tests and benches.
    Force,
    /// Legacy format v1 (no per-section encoding fields). Kept so
    /// compatibility tests and the v1-vs-v2 bench A/B can produce
    /// genuine v1 files; not reachable from the CLI.
    V1,
}

impl std::str::FromStr for Compress {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Compress> {
        match s {
            "none" => Ok(Compress::None),
            "auto" => Ok(Compress::Auto),
            "force" => Ok(Compress::Force),
            other => anyhow::bail!(
                "--compress: '{other}' is not a compression mode \
                 (none|auto|force)"
            ),
        }
    }
}

/// Everything case-specific the archive stores besides the blocks.
/// The manifest line is opaque to this layer — the coordinator renders
/// it from its `CaseConfig` and parses it back on load, which keeps
/// the trace tier independent of the simulation tier.
pub struct CaseMeta<'a> {
    /// Case name (used, sanitized, as the file-name stem).
    pub name: &'a str,
    /// Full config rendering (`case name=... steps=N`).
    pub manifest: &'a str,
    /// Group size the recording was made at (wavefront width).
    pub base_group_size: u32,
    /// Simulation seed — a [`case_key`] ingredient.
    pub seed: u64,
    pub final_field_energy: f64,
    pub final_kinetic_energy: f64,
}

/// Per-block index entry accumulated while streaming sections.
struct BlockIndex {
    n_records: u32,
    n_inst: u32,
    n_acc: u32,
    n_addr: u32,
    col_enc: [u8; COLUMNS],
    col_off: [u64; COLUMNS],
    col_len: [u64; COLUMNS],
    col_sum: [u64; COLUMNS],
}

/// Counting, checksumming writer over the temp file.
struct Out {
    w: BufWriter<File>,
    pos: u64,
}

impl Out {
    fn write(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        self.w.write_all(bytes)?;
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Write one column's stored bytes: the data, then zero pad to
    /// alignment; returns (offset, checksum over data + trailing pad).
    /// Leading padding is covered by the *previous* column's checksum,
    /// so coverage tiles the data region with no gaps.
    fn column(&mut self, data: &[u8]) -> anyhow::Result<(u64, u64)> {
        debug_assert_eq!(self.pos % 8, 0, "columns start aligned");
        let off = self.pos;
        let mut sum = Fnv::new();
        sum.write(data);
        self.write(data)?;
        let padded = align_up(data.len() as u64);
        let pad = [0u8; 8];
        let pad_n = (padded - data.len() as u64) as usize;
        sum.write(&pad[..pad_n]);
        self.write(&pad[..pad_n])?;
        Ok((off, sum.finish()))
    }
}

/// Write `dispatches` (the base-width recording of one case) as an
/// archive file in `dir`, atomically, with the default
/// [`Compress::Auto`] policy. Returns the final path. The file name
/// embeds the case's content key, so config changes produce new files
/// instead of overwriting unrelated recordings.
pub fn write_case_archive(
    dir: &Path,
    meta: &CaseMeta<'_>,
    dispatches: &[RecordedDispatch],
) -> anyhow::Result<PathBuf> {
    write_case_archive_with(dir, meta, dispatches, Compress::Auto)
}

/// [`write_case_archive`] with an explicit [`Compress`] policy.
pub fn write_case_archive_with(
    dir: &Path,
    meta: &CaseMeta<'_>,
    dispatches: &[RecordedDispatch],
    compress: Compress,
) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir).map_err(|e| {
        anyhow::anyhow!("create archive dir {}: {e}", dir.display())
    })?;
    let key =
        case_key(meta.manifest, meta.base_group_size, meta.seed);
    let final_path =
        dir.join(super::format::archive_file_name(meta.name, key));
    // unique per process AND per spill: two threads of one process
    // spilling the same case must not interleave into one temp file
    static SPILL_SEQ: std::sync::atomic::AtomicU64 =
        std::sync::atomic::AtomicU64::new(0);
    let tmp_path = dir.join(format!(
        ".{}.tmp.{}.{}",
        super::format::archive_file_name(meta.name, key),
        std::process::id(),
        SPILL_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));

    let res = write_to_tmp(&tmp_path, meta, key, dispatches, compress)
        .and_then(|()| {
            if let Some(e) = crate::fault::io_error("archive.rename")
            {
                return Err(anyhow::anyhow!(
                    "rename {} -> {}: {e}",
                    tmp_path.display(),
                    final_path.display()
                ));
            }
            std::fs::rename(&tmp_path, &final_path).map_err(|e| {
                anyhow::anyhow!(
                    "rename {} -> {}: {e}",
                    tmp_path.display(),
                    final_path.display()
                )
            })
        });
    if res.is_err() {
        // this process's failed spill cleans up after itself; temps
        // orphaned by a *crashed* process are swept by
        // `gc::sweep_stale_temps` (`trace-info --prune`)
        let _ = std::fs::remove_file(&tmp_path);
    }
    res.map(|()| final_path)
}

fn write_to_tmp(
    tmp_path: &Path,
    meta: &CaseMeta<'_>,
    key: u64,
    dispatches: &[RecordedDispatch],
    compress: Compress,
) -> anyhow::Result<()> {
    let version = match compress {
        Compress::V1 => MIN_FORMAT_VERSION,
        _ => FORMAT_VERSION,
    };
    if let Some(e) = crate::fault::io_error("archive.write") {
        return Err(anyhow::anyhow!(
            "write {}: {e}",
            tmp_path.display()
        ));
    }
    let file = File::create(tmp_path).map_err(|e| {
        anyhow::anyhow!("create {}: {e}", tmp_path.display())
    })?;
    let mut out = Out {
        w: BufWriter::new(file),
        pos: 0,
    };

    // -- header placeholder (patched at the end) ----------------------
    out.write(&[0u8; HEADER_LEN])?;

    // -- meta section --------------------------------------------------
    let mut mbuf: Vec<u8> = Vec::with_capacity(
        meta.manifest.len() + 32,
    );
    mbuf.extend_from_slice(
        &(meta.manifest.len() as u32).to_le_bytes(),
    );
    mbuf.extend_from_slice(meta.manifest.as_bytes());
    mbuf.extend_from_slice(
        &meta.final_field_energy.to_bits().to_le_bytes(),
    );
    mbuf.extend_from_slice(
        &meta.final_kinetic_energy.to_bits().to_le_bytes(),
    );
    let msum = super::format::fnv1a(&mbuf);
    mbuf.extend_from_slice(&msum.to_le_bytes());
    let meta_len = mbuf.len() as u64;
    out.write(&mbuf)?;
    // align the first column; the gap is dead space (validated zero by
    // nothing — it is never read)
    let pad = align_up(out.pos) - out.pos;
    out.write(&[0u8; 8][..pad as usize])?;

    // -- column sections ----------------------------------------------
    let mut index: Vec<(String, Vec<BlockIndex>)> =
        Vec::with_capacity(dispatches.len());
    let mut colbuf: Vec<u8> = Vec::new();
    let mut encbuf: Vec<u8> = Vec::new();
    for d in dispatches {
        let mut blocks = Vec::with_capacity(d.blocks.len());
        for b in d.blocks.iter() {
            let cols = b.columns();
            let mut e = BlockIndex {
                n_records: cols.tags.len() as u32,
                n_inst: cols.inst_class.len() as u32,
                n_acc: cols.acc_kind.len() as u32,
                n_addr: cols.addrs.len() as u32,
                col_enc: [Encoding::Raw.to_u8(); COLUMNS],
                col_off: [0; COLUMNS],
                col_len: [0; COLUMNS],
                col_sum: [0; COLUMNS],
            };
            // wire order: tags, group_ids, inst_class, inst_count,
            // acc_kind, acc_bpl, acc_off, acc_len, addrs
            for c in 0..COLUMNS {
                colbuf.clear();
                match c {
                    0 => colbuf.extend(
                        cols.tags.iter().map(|t| tag_to_u8(*t)),
                    ),
                    1 => push_u64s(&mut colbuf, cols.group_ids),
                    2 => colbuf.extend(
                        cols.inst_class
                            .iter()
                            .map(|x| class_to_u8(*x)),
                    ),
                    3 => push_u64s(&mut colbuf, cols.inst_count),
                    4 => colbuf.extend(
                        cols.acc_kind.iter().map(|k| kind_to_u8(*k)),
                    ),
                    5 => colbuf.extend_from_slice(cols.acc_bpl),
                    6 => push_u32s(&mut colbuf, cols.acc_off),
                    7 => colbuf.extend_from_slice(cols.acc_len),
                    _ => push_u64s(&mut colbuf, cols.addrs),
                }
                let (enc, stored): (Encoding, &[u8]) = match compress
                {
                    Compress::V1 | Compress::None => {
                        (Encoding::Raw, colbuf.as_slice())
                    }
                    Compress::Force => {
                        let enc = codec::encode(
                            &colbuf,
                            COLUMN_WIDTHS[c],
                            &mut encbuf,
                        );
                        (enc, encbuf.as_slice())
                    }
                    Compress::Auto => {
                        let enc = codec::encode(
                            &colbuf,
                            COLUMN_WIDTHS[c],
                            &mut encbuf,
                        );
                        // measured, per section: compression must
                        // actually pay, else keep the raw zero-copy
                        // mapped form
                        if encbuf.len() < colbuf.len() {
                            (enc, encbuf.as_slice())
                        } else {
                            (Encoding::Raw, colbuf.as_slice())
                        }
                    }
                };
                let (off, sum) = out.column(stored)?;
                e.col_enc[c] = enc.to_u8();
                e.col_off[c] = off;
                e.col_len[c] = stored.len() as u64;
                e.col_sum[c] = sum;
            }
            blocks.push(e);
        }
        index.push((d.kernel.clone(), blocks));
    }

    // -- index ---------------------------------------------------------
    let index_off = out.pos;
    let mut ibuf: Vec<u8> = Vec::new();
    for (kernel, blocks) in &index {
        anyhow::ensure!(
            kernel.len() <= u16::MAX as usize,
            "kernel name too long: {kernel}"
        );
        ibuf.extend_from_slice(
            &(kernel.len() as u16).to_le_bytes(),
        );
        ibuf.extend_from_slice(kernel.as_bytes());
        ibuf.extend_from_slice(
            &(blocks.len() as u32).to_le_bytes(),
        );
        for b in blocks {
            ibuf.extend_from_slice(&b.n_records.to_le_bytes());
            ibuf.extend_from_slice(&b.n_inst.to_le_bytes());
            ibuf.extend_from_slice(&b.n_acc.to_le_bytes());
            ibuf.extend_from_slice(&b.n_addr.to_le_bytes());
            if version >= 2 {
                // v2: one encoding byte and one stored length per
                // section (v1 stores neither — all sections raw, with
                // lengths derived from the counts)
                ibuf.extend_from_slice(&b.col_enc);
                for c in 0..COLUMNS {
                    ibuf.extend_from_slice(
                        &b.col_len[c].to_le_bytes(),
                    );
                }
            }
            for c in 0..COLUMNS {
                ibuf.extend_from_slice(&b.col_off[c].to_le_bytes());
            }
            for c in 0..COLUMNS {
                ibuf.extend_from_slice(&b.col_sum[c].to_le_bytes());
            }
        }
    }
    let isum = super::format::fnv1a(&ibuf);
    ibuf.extend_from_slice(&isum.to_le_bytes());
    let index_len = ibuf.len() as u64;
    out.write(&ibuf)?;

    // -- patched header ------------------------------------------------
    let mut h = Vec::with_capacity(HEADER_LEN);
    h.extend_from_slice(&MAGIC);
    h.extend_from_slice(&version.to_le_bytes());
    h.extend_from_slice(&ENDIAN_TAG.to_le_bytes());
    h.extend_from_slice(&meta.base_group_size.to_le_bytes());
    h.extend_from_slice(
        &(dispatches.len() as u32).to_le_bytes(),
    );
    h.extend_from_slice(&key.to_le_bytes());
    h.extend_from_slice(&meta_len.to_le_bytes());
    h.extend_from_slice(&index_off.to_le_bytes());
    h.extend_from_slice(&index_len.to_le_bytes());
    debug_assert_eq!(h.len(), HEADER_LEN - 8);
    let hsum = super::format::fnv1a(&h);
    h.extend_from_slice(&hsum.to_le_bytes());

    out.w.flush()?;
    let mut file = out.w.into_inner().map_err(|e| {
        anyhow::anyhow!("flush {}: {e}", tmp_path.display())
    })?;
    file.seek(SeekFrom::Start(0))?;
    file.write_all(&h)?;
    if let Some(e) = crate::fault::io_error("archive.sync") {
        return Err(anyhow::anyhow!(
            "sync {}: {e}",
            tmp_path.display()
        ));
    }
    // durability before the rename publishes the file
    file.sync_all()?;
    Ok(())
}

fn push_u64s(dst: &mut Vec<u8>, vals: &[u64]) {
    dst.reserve(vals.len() * 8);
    for v in vals {
        dst.extend_from_slice(&v.to_le_bytes());
    }
}

fn push_u32s(dst: &mut Vec<u8>, vals: &[u32]) {
    dst.reserve(vals.len() * 4);
    for v in vals {
        dst.extend_from_slice(&v.to_le_bytes());
    }
}
