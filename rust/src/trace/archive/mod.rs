//! Persistent trace archive: the disk tier of record-once /
//! replay-everywhere.
//!
//! PR 2 made sweeps record each case's trace exactly once per
//! *process*; this subsystem makes recordings survive the process. A
//! case's [`crate::trace::EventBlock`]s are laid out as aligned,
//! checksummed column sections in a versioned little-endian file
//! ([`format`], specified in `docs/trace-format.md`), written
//! atomically ([`writer`] — temp file + rename, safe under concurrent
//! shard processes) and memory-mapped back ([`reader`], [`mmap`]) for
//! **zero-copy** replay: [`MappedBlock`] implements
//! [`crate::trace::BlockData`], so borrowed records are reconstructed
//! straight from the mapped columns and stream through
//! `ProfileSession::profile_blocks_scaled` bit-identically to live
//! tracing — on every GPU preset, including V100's derived
//! half-group form.
//!
//! Format **v2** adds optional per-section column compression
//! ([`codec`]: delta+varint for the wide integer columns, RLE for the
//! byte columns), selected per section by the writer's measured-ratio
//! heuristic ([`Compress::Auto`]) — raw sections keep the zero-copy
//! mapped path, compressed sections decode once at open into a pooled
//! arena, and replay is bit-identical either way (v1 files remain
//! readable).
//!
//! [`StreamingCaseTrace`] is the **out-of-core** tier on top of the
//! same format: open reads only the index, each dispatch's sections
//! are decoded on demand into recycled per-dispatch arenas
//! (decode-ahead on the worker pool overlapping replay), and peak
//! memory stays bounded however large the archive — with replay
//! still bit-identical to the mapped tier.
//!
//! Files are content-addressed: the name embeds
//! [`format::case_key`], a hash of the case config manifest, the
//! recording group size, the simulation seed and the format version —
//! a config change re-keys the file rather than silently replaying a
//! stale recording. CI exploits this: a record-once pre-job builds
//! the archive, caches it under the combined case key, and every
//! `--shard i/n` job replays from the shared cache with **zero** live
//! recordings (`TraceStore` counts them; the sweep fails closed under
//! `ROCLINE_REQUIRE_ARCHIVE_HIT=1`).

pub mod codec;
pub mod format;
pub mod gc;
mod mmap;
pub mod reader;
pub mod writer;

pub use codec::Encoding;
pub use format::{
    archive_file_name, case_key, fnv1a, FORMAT_VERSION,
    MIN_FORMAT_VERSION,
};
pub use gc::{prune_dir, sweep_stale_temps, PruneReport};
pub use reader::{
    ArchiveInfo, ColumnStats, MappedBlock, MappedCaseTrace,
    MappedDispatch, StreamedDispatch, StreamingCaseTrace,
};
pub use writer::{
    write_case_archive, write_case_archive_with, CaseMeta, Compress,
};
