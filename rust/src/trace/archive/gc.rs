//! Archive garbage collection: delete `.rtrc` files whose content
//! keys are no longer live, and sweep temp files orphaned by crashed
//! spills.
//!
//! Archive files are content-addressed
//! ([`super::format::archive_file_name`] embeds the case key), so a
//! config, seed or format change writes a *new* file and leaves the
//! old one behind. In long-lived CI caches and developer `--trace-dir`
//! directories those dead recordings accumulate without bound — they
//! can never hit again, because nothing computes their key anymore.
//! [`prune_dir`] removes exactly those: everything with the archive
//! extension whose file name is not in the caller's live set. It
//! never touches non-archive files, and it never deletes a live key,
//! however stale its mtime — content addressing, not age, decides.
//!
//! **Stale spill temps.** The writer assembles each archive under a
//! dot-temp name (`.{name}.{EXTENSION}.tmp.{pid}.{seq}`) and removes
//! it on its own error paths — but a spill interrupted by a crash or
//! `SIGKILL` leaves the temp behind forever: `prune_dir`'s extension
//! filter skips it (its trailing extension is the numeric `{seq}`,
//! not `rtrc`), so nothing ever reclaimed it. [`sweep_stale_temps`]
//! (run by `trace-info --prune` and by [`prune_dir`] itself) deletes
//! exactly the temps whose *owning process is gone* — a live spill's
//! temp (pid alive, possibly another shard mid-write) is never
//! touched, and names that don't match the writer's temp pattern are
//! ignored.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use super::format::EXTENSION;

/// What [`prune_dir`] did, for reporting and tests.
pub struct PruneReport {
    /// Archive files whose names were in the live set (sorted).
    pub kept: Vec<PathBuf>,
    /// Archive files deleted as dead keys (sorted).
    pub deleted: Vec<PathBuf>,
    /// Spill temp files swept because their owning process is gone
    /// (sorted).
    pub swept_temps: Vec<PathBuf>,
}

/// Parse the pid out of a writer temp-file name
/// (`.{stem}.{EXTENSION}.tmp.{pid}.{seq}`); `None` when the name is
/// not a spill temp.
fn temp_file_pid(name: &str) -> Option<u32> {
    let marker = format!(".{EXTENSION}.tmp.");
    let rest = name
        .strip_prefix('.')?
        .split_once(marker.as_str())?
        .1;
    let (pid, seq) = rest.split_once('.')?;
    // both halves must be numeric, exactly as the writer formats them
    if seq.is_empty() || !seq.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    pid.parse::<u32>().ok()
}

/// Whether `pid` is a live process on this host. On unix this asks the
/// kernel (`kill(pid, 0)`: EPERM still means *alive*); elsewhere it
/// conservatively answers `true` (never sweep what we cannot check).
#[cfg(unix)]
fn pid_alive(pid: u32) -> bool {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    if pid > i32::MAX as u32 {
        return false;
    }
    // SAFETY: signal 0 performs permission/existence checks only —
    // no signal is delivered to anyone.
    let ret = unsafe { kill(pid as i32, 0) };
    const EPERM: i32 = 1;
    ret == 0
        || std::io::Error::last_os_error().raw_os_error()
            == Some(EPERM)
}

#[cfg(not(unix))]
fn pid_alive(_pid: u32) -> bool {
    true
}

/// Delete every spill temp file in `dir` whose owning process no
/// longer exists (see the module docs). Returns the deleted paths,
/// sorted. Non-temp files — including complete `.rtrc` archives and
/// temps of *live* spills — are never touched.
pub fn sweep_stale_temps(
    dir: &Path,
) -> anyhow::Result<Vec<PathBuf>> {
    let entries = std::fs::read_dir(dir).map_err(|e| {
        anyhow::anyhow!("read archive dir {}: {e}", dir.display())
    })?;
    let mut swept = Vec::new();
    for entry in entries {
        let path = entry
            .map_err(|e| {
                anyhow::anyhow!(
                    "read archive dir {}: {e}",
                    dir.display()
                )
            })?
            .path();
        let Some(name) = path.file_name().and_then(|n| n.to_str())
        else {
            continue;
        };
        let Some(pid) = temp_file_pid(name) else {
            continue;
        };
        if pid_alive(pid) {
            continue;
        }
        std::fs::remove_file(&path).map_err(|e| {
            anyhow::anyhow!("delete {}: {e}", path.display())
        })?;
        swept.push(path);
    }
    swept.sort();
    Ok(swept)
}

/// Delete every `.rtrc` file in `dir` whose file name is **not** in
/// `live` (the content-addressed names of the current case set, e.g.
/// from [`crate::coordinator::CaseTrace::archive_path`]), and sweep
/// spill temps orphaned by dead processes ([`sweep_stale_temps`]).
/// Returns the kept/deleted/swept partition. Other non-archive files
/// are ignored; a missing directory is an error (pruning a path that
/// never held an archive is almost certainly a typo, not a no-op).
pub fn prune_dir(
    dir: &Path,
    live: &HashSet<String>,
) -> anyhow::Result<PruneReport> {
    let mut report = PruneReport {
        kept: Vec::new(),
        deleted: Vec::new(),
        swept_temps: sweep_stale_temps(dir)?,
    };
    let entries = std::fs::read_dir(dir).map_err(|e| {
        anyhow::anyhow!("read archive dir {}: {e}", dir.display())
    })?;
    for entry in entries {
        let path = match entry {
            Ok(e) => e.path(),
            Err(e) => {
                anyhow::bail!(
                    "read archive dir {}: {e}",
                    dir.display()
                )
            }
        };
        if path.extension().and_then(|x| x.to_str())
            != Some(EXTENSION)
        {
            continue;
        }
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if live.contains(&name) {
            report.kept.push(path);
        } else {
            std::fs::remove_file(&path).map_err(|e| {
                anyhow::anyhow!("delete {}: {e}", path.display())
            })?;
            report.deleted.push(path);
        }
    }
    report.kept.sort();
    report.deleted.sort();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "rocline-gc-test-{}-{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn touch(dir: &Path, name: &str) {
        let mut f =
            std::fs::File::create(dir.join(name)).unwrap();
        f.write_all(b"x").unwrap();
    }

    #[test]
    fn prune_deletes_dead_keys_and_keeps_live_ones() {
        let dir = tmp_dir("basic");
        touch(&dir, "a-0000000000000001.rtrc");
        touch(&dir, "b-0000000000000002.rtrc");
        touch(&dir, "notes.txt"); // non-archive: never touched
        let live: HashSet<String> =
            ["a-0000000000000001.rtrc".to_string()]
                .into_iter()
                .collect();
        let report = prune_dir(&dir, &live).unwrap();
        assert_eq!(report.kept.len(), 1);
        assert_eq!(report.deleted.len(), 1);
        assert!(dir.join("a-0000000000000001.rtrc").exists());
        assert!(!dir.join("b-0000000000000002.rtrc").exists());
        assert!(dir.join("notes.txt").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_with_all_keys_live_deletes_nothing() {
        let dir = tmp_dir("all-live");
        touch(&dir, "a-0000000000000001.rtrc");
        let live: HashSet<String> =
            ["a-0000000000000001.rtrc".to_string()]
                .into_iter()
                .collect();
        let report = prune_dir(&dir, &live).unwrap();
        assert_eq!(report.kept.len(), 1);
        assert!(report.deleted.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn temp_names_parse_only_the_writer_pattern() {
        assert_eq!(
            temp_file_pid(".tiny-0000000000000abc.rtrc.tmp.4242.7"),
            Some(4242)
        );
        // not temps: complete archives, non-dot files, malformed tails
        assert_eq!(
            temp_file_pid("tiny-0000000000000abc.rtrc"),
            None
        );
        assert_eq!(
            temp_file_pid("tiny.rtrc.tmp.4242.7"),
            None,
            "temps always start with a dot"
        );
        assert_eq!(temp_file_pid(".tiny.rtrc.tmp.notpid.7"), None);
        assert_eq!(temp_file_pid(".tiny.rtrc.tmp.4242.x"), None);
        assert_eq!(temp_file_pid(".tiny.rtrc.tmp.4242"), None);
        assert_eq!(temp_file_pid(".notes.txt"), None);
    }

    #[test]
    fn sweep_deletes_dead_pid_temps_and_keeps_live_ones() {
        let dir = tmp_dir("temps");
        // a stale temp from a crashed spill: linux pids never
        // exceed 2^22 (kernel pid_max ceiling), so this pid is
        // guaranteed dead
        let stale = ".tiny-0000000000000001.rtrc.tmp.4200000.3";
        touch(&dir, stale);
        // a temp owned by *this* process: a live spill, never swept
        let live = format!(
            ".tiny-0000000000000002.rtrc.tmp.{}.0",
            std::process::id()
        );
        touch(&dir, &live);
        // bystanders
        touch(&dir, "tiny-0000000000000003.rtrc");
        touch(&dir, "notes.txt");

        let swept = sweep_stale_temps(&dir).unwrap();
        assert_eq!(swept, vec![dir.join(stale)]);
        assert!(!dir.join(stale).exists());
        assert!(dir.join(&live).exists(), "live spill kept");
        assert!(dir.join("tiny-0000000000000003.rtrc").exists());
        assert!(dir.join("notes.txt").exists());

        // idempotent
        assert!(sweep_stale_temps(&dir).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_sweeps_stale_temps_too() {
        let dir = tmp_dir("prune-temps");
        let stale = ".a-0000000000000001.rtrc.tmp.4200001.0";
        touch(&dir, stale);
        touch(&dir, "a-0000000000000001.rtrc");
        let live: HashSet<String> =
            ["a-0000000000000001.rtrc".to_string()]
                .into_iter()
                .collect();
        let report = prune_dir(&dir, &live).unwrap();
        assert_eq!(report.kept.len(), 1);
        assert!(report.deleted.is_empty());
        assert_eq!(report.swept_temps, vec![dir.join(stale)]);
        assert!(!dir.join(stale).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_missing_dir_is_a_clean_error() {
        let err = prune_dir(
            Path::new("/nonexistent-rocline-gc"),
            &HashSet::new(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("read archive dir"), "{err}");
    }
}
