//! Archive garbage collection: delete `.rtrc` files whose content
//! keys are no longer live.
//!
//! Archive files are content-addressed
//! ([`super::format::archive_file_name`] embeds the case key), so a
//! config, seed or format change writes a *new* file and leaves the
//! old one behind. In long-lived CI caches and developer `--trace-dir`
//! directories those dead recordings accumulate without bound — they
//! can never hit again, because nothing computes their key anymore.
//! [`prune_dir`] removes exactly those: everything with the archive
//! extension whose file name is not in the caller's live set. It
//! never touches non-archive files, and it never deletes a live key,
//! however stale its mtime — content addressing, not age, decides.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use super::format::EXTENSION;

/// What [`prune_dir`] did, for reporting and tests.
pub struct PruneReport {
    /// Archive files whose names were in the live set (sorted).
    pub kept: Vec<PathBuf>,
    /// Archive files deleted as dead keys (sorted).
    pub deleted: Vec<PathBuf>,
}

/// Delete every `.rtrc` file in `dir` whose file name is **not** in
/// `live` (the content-addressed names of the current case set, e.g.
/// from [`crate::coordinator::CaseTrace::archive_path`]). Returns the
/// kept/deleted partition. Non-archive files are ignored; a missing
/// directory is an error (pruning a path that never held an archive
/// is almost certainly a typo, not a no-op).
pub fn prune_dir(
    dir: &Path,
    live: &HashSet<String>,
) -> anyhow::Result<PruneReport> {
    let mut report = PruneReport {
        kept: Vec::new(),
        deleted: Vec::new(),
    };
    let entries = std::fs::read_dir(dir).map_err(|e| {
        anyhow::anyhow!("read archive dir {}: {e}", dir.display())
    })?;
    for entry in entries {
        let path = match entry {
            Ok(e) => e.path(),
            Err(e) => {
                anyhow::bail!(
                    "read archive dir {}: {e}",
                    dir.display()
                )
            }
        };
        if path.extension().and_then(|x| x.to_str())
            != Some(EXTENSION)
        {
            continue;
        }
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if live.contains(&name) {
            report.kept.push(path);
        } else {
            std::fs::remove_file(&path).map_err(|e| {
                anyhow::anyhow!("delete {}: {e}", path.display())
            })?;
            report.deleted.push(path);
        }
    }
    report.kept.sort();
    report.deleted.sort();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "rocline-gc-test-{}-{name}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn touch(dir: &Path, name: &str) {
        let mut f =
            std::fs::File::create(dir.join(name)).unwrap();
        f.write_all(b"x").unwrap();
    }

    #[test]
    fn prune_deletes_dead_keys_and_keeps_live_ones() {
        let dir = tmp_dir("basic");
        touch(&dir, "a-0000000000000001.rtrc");
        touch(&dir, "b-0000000000000002.rtrc");
        touch(&dir, "notes.txt"); // non-archive: never touched
        let live: HashSet<String> =
            ["a-0000000000000001.rtrc".to_string()]
                .into_iter()
                .collect();
        let report = prune_dir(&dir, &live).unwrap();
        assert_eq!(report.kept.len(), 1);
        assert_eq!(report.deleted.len(), 1);
        assert!(dir.join("a-0000000000000001.rtrc").exists());
        assert!(!dir.join("b-0000000000000002.rtrc").exists());
        assert!(dir.join("notes.txt").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_with_all_keys_live_deletes_nothing() {
        let dir = tmp_dir("all-live");
        touch(&dir, "a-0000000000000001.rtrc");
        let live: HashSet<String> =
            ["a-0000000000000001.rtrc".to_string()]
                .into_iter()
                .collect();
        let report = prune_dir(&dir, &live).unwrap();
        assert_eq!(report.kept.len(), 1);
        assert!(report.deleted.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prune_missing_dir_is_a_clean_error() {
        let err = prune_dir(
            Path::new("/nonexistent-rocline-gc"),
            &HashSet::new(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("read archive dir"), "{err}");
    }
}
