//! Read-only file mappings for zero-copy archive replay.
//!
//! [`ArchiveBuf`] is the byte storage behind every mapped archive:
//! on 64-bit unix it is a real `mmap(2)` of the file (no crates — the
//! registry is offline, so the two syscalls are declared directly
//! against the C runtime the Rust std already links); elsewhere, or if
//! the mapping fails, it falls back to reading the file into an
//! 8-byte-aligned heap buffer. Either way [`ArchiveBuf::bytes`] hands
//! out one immutable `&[u8]` whose base address is at least 8-aligned,
//! which (with the format's aligned column offsets) is what makes the
//! reader's `&[u64]` column views sound.
//!
//! Safety model: archives are written atomically (temp file + rename)
//! and never modified in place, so a mapping's contents are stable for
//! its lifetime. A reader that races a *delete* keeps its mapping
//! alive (unix semantics); truncating an archive in place is the one
//! unsupported mutation (as with every mmap consumer, it could fault),
//! and nothing in this crate does it.

use std::fs::File;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }

    pub const PROT_READ: i32 = 0x1;
    pub const MAP_PRIVATE: i32 = 0x2;
}

/// A read-only `mmap` of a whole file.
#[cfg(all(unix, target_pointer_width = "64"))]
pub(crate) struct Mmap {
    ptr: std::ptr::NonNull<u8>,
    len: usize,
}

// SAFETY: the mapping is PROT_READ and never aliased mutably; sharing
// immutable views across threads is sound.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for Mmap {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for Mmap {}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Mmap {
    fn map(file: &File, len: usize) -> anyhow::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        anyhow::ensure!(len > 0, "cannot map an empty file");
        // SAFETY: a fresh private read-only mapping of `len` bytes of
        // an open fd; the result is checked against MAP_FAILED before
        // use and unmapped exactly once in Drop.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as usize == usize::MAX || ptr.is_null() {
            anyhow::bail!(
                "mmap failed: {}",
                std::io::Error::last_os_error()
            );
        }
        let ptr = std::ptr::NonNull::new(ptr.cast::<u8>())
            .expect("checked non-null above");
        Ok(Mmap { ptr, len })
    }

    fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len describe a live PROT_READ mapping owned by
        // self; the slice's lifetime is tied to &self.
        unsafe {
            std::slice::from_raw_parts(self.ptr.as_ptr(), self.len)
        }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: mapping created by us in `map`, unmapped once.
        unsafe {
            sys::munmap(self.ptr.as_ptr().cast(), self.len);
        }
    }
}

/// An 8-byte-aligned owned byte buffer (`u64` storage guarantees base
/// alignment). Two users: the no-mmap fallback of [`ArchiveBuf`], and
/// the reader's pooled decode arena for compressed v2 sections —
/// decoded column images need the same alignment guarantee as mapped
/// ones so `&[u64]`/`&[u32]` views stay sound.
#[derive(Default)]
pub(crate) struct OwnedBytes {
    words: Vec<u64>,
    /// Logical length (`words` may be padded by up to 7 bytes).
    len: usize,
}

impl OwnedBytes {
    /// An empty buffer with room for `cap` bytes.
    pub(crate) fn with_capacity(cap: usize) -> OwnedBytes {
        OwnedBytes {
            words: Vec::with_capacity(cap.div_ceil(8)),
            len: 0,
        }
    }

    /// Append `bytes` at the next 8-byte boundary (the gap, if any, is
    /// zero) — every append therefore starts aligned, which is what
    /// makes appended section images directly sliceable as their
    /// element type. Returns the byte offset `bytes` landed at.
    pub(crate) fn push_aligned(&mut self, bytes: &[u8]) -> usize {
        let off = self.len.div_ceil(8) * 8;
        let end = off + bytes.len();
        self.words.resize(end.div_ceil(8), 0);
        // SAFETY: viewing the u64 storage as bytes; u8 has no validity
        // or alignment requirements, and `end` is within the storage.
        let dst = unsafe {
            std::slice::from_raw_parts_mut(
                self.words.as_mut_ptr().cast::<u8>(),
                self.words.len() * 8,
            )
        };
        dst[off..end].copy_from_slice(bytes);
        self.len = end;
        off
    }

    /// The logical bytes. Base address is 8-aligned.
    pub(crate) fn bytes(&self) -> &[u8] {
        // SAFETY: words holds at least `len` initialized bytes.
        unsafe {
            std::slice::from_raw_parts(
                self.words.as_ptr().cast::<u8>(),
                self.len,
            )
        }
    }

    /// Reclaim the backing `u64` storage (capacity and all) — the
    /// streaming reader's bounded decode-buffer pool recycles arenas
    /// through this instead of reallocating per dispatch.
    pub(crate) fn into_words(self) -> Vec<u64> {
        self.words
    }

    /// An empty buffer over recycled storage: keeps the words'
    /// capacity, discards their contents.
    pub(crate) fn from_recycled(mut words: Vec<u64>) -> OwnedBytes {
        words.clear();
        OwnedBytes { words, len: 0 }
    }

    /// Bytes of heap actually reserved (≥ `bytes().len()`); the
    /// streaming reader's peak-memory accounting charges this, not
    /// the logical length, so pool growth is what gets measured.
    pub(crate) fn capacity_bytes(&self) -> usize {
        self.words.capacity() * 8
    }
}

/// Backing bytes of an opened archive: a zero-copy file mapping where
/// available, an aligned owned buffer otherwise.
pub(crate) enum ArchiveBuf {
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mapped(Mmap),
    Owned(OwnedBytes),
}

impl ArchiveBuf {
    /// Load (preferably map) the whole file.
    pub(crate) fn load(file: &File) -> anyhow::Result<ArchiveBuf> {
        if let Some(e) = crate::fault::io_error("archive.mmap") {
            return Err(e.into());
        }
        let len = file.metadata()?.len();
        anyhow::ensure!(len > 0, "corrupt archive: empty file");
        anyhow::ensure!(
            len <= usize::MAX as u64,
            "archive too large to map ({len} bytes)"
        );
        let len = len as usize;
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            match Mmap::map(file, len) {
                Ok(m) => return Ok(ArchiveBuf::Mapped(m)),
                Err(e) => eprintln!(
                    "warning: mmap unavailable, reading archive into \
                     memory: {e:#}"
                ),
            }
        }
        Self::read_owned(file, len)
    }

    /// Fallback: read the file into an 8-aligned heap buffer.
    fn read_owned(file: &File, len: usize) -> anyhow::Result<ArchiveBuf> {
        use std::io::{Read, Seek, SeekFrom};
        let mut owned = OwnedBytes {
            words: vec![0u64; len.div_ceil(8)],
            len,
        };
        {
            // SAFETY: viewing the zero-initialized u64 buffer as bytes;
            // u8 has no validity or alignment requirements.
            let bytes = unsafe {
                std::slice::from_raw_parts_mut(
                    owned.words.as_mut_ptr().cast::<u8>(),
                    len,
                )
            };
            let mut f = file;
            f.seek(SeekFrom::Start(0))?;
            f.read_exact(bytes)?;
        }
        Ok(ArchiveBuf::Owned(owned))
    }

    /// The file's bytes. The base address is always at least 8-byte
    /// aligned (page-aligned mapping, or `Vec<u64>` storage).
    pub(crate) fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(all(unix, target_pointer_width = "64"))]
            ArchiveBuf::Mapped(m) => m.bytes(),
            ArchiveBuf::Owned(owned) => owned.bytes(),
        }
    }

    /// True when backed by a real file mapping (telemetry/tests).
    pub(crate) fn is_mapped(&self) -> bool {
        match self {
            #[cfg(all(unix, target_pointer_width = "64"))]
            ArchiveBuf::Mapped(_) => true,
            ArchiveBuf::Owned { .. } => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_file(name: &str, contents: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!(
            "rocline-mmap-test-{}-{name}",
            std::process::id()
        ));
        let mut f = File::create(&p).unwrap();
        f.write_all(contents).unwrap();
        f.sync_all().unwrap();
        p
    }

    #[test]
    fn load_round_trips_bytes_and_aligns_base() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let p = tmp_file("roundtrip", &data);
        let buf = ArchiveBuf::load(&File::open(&p).unwrap()).unwrap();
        assert_eq!(buf.bytes(), &data[..]);
        assert_eq!(buf.bytes().as_ptr() as usize % 8, 0);
        drop(buf);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn owned_fallback_round_trips_too() {
        let data = vec![7u8; 37];
        let p = tmp_file("owned", &data);
        let f = File::open(&p).unwrap();
        let buf = ArchiveBuf::read_owned(&f, data.len()).unwrap();
        assert!(!buf.is_mapped());
        assert_eq!(buf.bytes(), &data[..]);
        assert_eq!(buf.bytes().as_ptr() as usize % 8, 0);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn owned_bytes_appends_stay_aligned() {
        let mut o = OwnedBytes::with_capacity(16);
        let a = o.push_aligned(&[1, 2, 3]);
        let b = o.push_aligned(&[4; 9]);
        let c = o.push_aligned(&[]);
        assert_eq!(a, 0);
        assert_eq!(b, 8, "second append starts at the next boundary");
        assert_eq!(c, 24);
        let bytes = o.bytes();
        assert_eq!(bytes.len(), 17 + 7, "len is the last append's end");
        assert_eq!(&bytes[..3], &[1, 2, 3]);
        assert_eq!(&bytes[3..8], &[0; 5], "gap is zero");
        assert_eq!(&bytes[8..17], &[4; 9]);
        assert_eq!(bytes.as_ptr() as usize % 8, 0);
    }

    #[test]
    fn recycled_storage_keeps_capacity_and_stays_aligned() {
        let mut o = OwnedBytes::with_capacity(64);
        o.push_aligned(&[9u8; 40]);
        let cap = o.capacity_bytes();
        assert!(cap >= 40);
        let words = o.into_words();
        let mut o2 = OwnedBytes::from_recycled(words);
        assert_eq!(o2.bytes().len(), 0, "recycled buffer starts empty");
        assert!(o2.capacity_bytes() >= cap, "capacity survives");
        let off = o2.push_aligned(&[1, 2, 3, 4]);
        assert_eq!(off, 0);
        assert_eq!(o2.bytes(), &[1, 2, 3, 4]);
        assert_eq!(o2.bytes().as_ptr() as usize % 8, 0);
    }

    #[test]
    fn empty_file_is_a_clean_error() {
        let p = tmp_file("empty", &[]);
        let err = ArchiveBuf::load(&File::open(&p).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("empty"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }
}
