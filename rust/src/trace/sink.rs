//! Event sinks: consumers of a replayed trace.

use super::event::{GroupCtx, LdsAccess, MemAccess};
use crate::arch::InstClass;

/// Consumer of group-level trace events.
///
/// Conventions:
/// * `on_mem`/`on_lds` each represent exactly **one** issued memory
///   instruction (sinks that count instructions must count them);
/// * `on_inst` is for non-memory instructions only, batched via `count`.
pub trait EventSink {
    fn on_inst(&mut self, ctx: &GroupCtx, class: InstClass, count: u64);
    fn on_mem(&mut self, ctx: &GroupCtx, access: &MemAccess);
    fn on_lds(&mut self, ctx: &GroupCtx, access: &LdsAccess);
}

/// Discards everything (baseline for bench comparisons).
#[derive(Debug, Default)]
pub struct NullSink;

impl EventSink for NullSink {
    fn on_inst(&mut self, _: &GroupCtx, _: InstClass, _: u64) {}
    fn on_mem(&mut self, _: &GroupCtx, _: &MemAccess) {}
    fn on_lds(&mut self, _: &GroupCtx, _: &LdsAccess) {}
}

/// Fans one replay out to several sinks (e.g. counter engine + memory
/// hierarchy + timing accumulator in a single pass over the trace).
pub struct FanoutSink<'a> {
    sinks: Vec<&'a mut dyn EventSink>,
}

impl<'a> FanoutSink<'a> {
    pub fn new(sinks: Vec<&'a mut dyn EventSink>) -> Self {
        FanoutSink { sinks }
    }
}

impl EventSink for FanoutSink<'_> {
    fn on_inst(&mut self, ctx: &GroupCtx, class: InstClass, count: u64) {
        for s in self.sinks.iter_mut() {
            s.on_inst(ctx, class, count);
        }
    }
    fn on_mem(&mut self, ctx: &GroupCtx, access: &MemAccess) {
        for s in self.sinks.iter_mut() {
            s.on_mem(ctx, access);
        }
    }
    fn on_lds(&mut self, ctx: &GroupCtx, access: &LdsAccess) {
        for s in self.sinks.iter_mut() {
            s.on_lds(ctx, access);
        }
    }
}

/// Applies an ISA-expansion factor to instruction counts on the way
/// through (exact identity at 1.0). This is how expansion-neutral
/// *recorded* traces are specialized to a GPU at replay time: memory
/// and LDS events pass through untouched, compute-class counts scale
/// by [`InstClass::expand_count`] — the same rounding the live trace
/// generators apply at emit time.
pub struct ScaleInstSink<'a> {
    inner: &'a mut dyn EventSink,
    expansion: f64,
}

impl<'a> ScaleInstSink<'a> {
    pub fn new(inner: &'a mut dyn EventSink, expansion: f64) -> Self {
        ScaleInstSink { inner, expansion }
    }
}

impl EventSink for ScaleInstSink<'_> {
    fn on_inst(&mut self, ctx: &GroupCtx, class: InstClass, count: u64) {
        self.inner.on_inst(
            ctx,
            class,
            class.expand_count(count, self.expansion),
        );
    }
    fn on_mem(&mut self, ctx: &GroupCtx, access: &MemAccess) {
        self.inner.on_mem(ctx, access);
    }
    fn on_lds(&mut self, ctx: &GroupCtx, access: &LdsAccess) {
        self.inner.on_lds(ctx, access);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::MemKind;

    #[derive(Default)]
    struct Count {
        inst: u64,
        mem: u64,
        lds: u64,
    }

    impl EventSink for Count {
        fn on_inst(&mut self, _: &GroupCtx, _: InstClass, n: u64) {
            self.inst += n;
        }
        fn on_mem(&mut self, _: &GroupCtx, _: &MemAccess) {
            self.mem += 1;
        }
        fn on_lds(&mut self, _: &GroupCtx, _: &LdsAccess) {
            self.lds += 1;
        }
    }

    #[test]
    fn scale_sink_expands_compute_and_forwards_memory() {
        let mut inner = Count::default();
        {
            let mut scaled = ScaleInstSink::new(&mut inner, 3.0);
            let ctx = GroupCtx { group_id: 0 };
            scaled.on_inst(&ctx, InstClass::ValuArith, 10);
            scaled.on_inst(&ctx, InstClass::Branch, 10);
            scaled.on_mem(
                &ctx,
                &MemAccess::contiguous(MemKind::Read, 0, 32, 4),
            );
        }
        // 10 valu -> 30, 10 branch -> 10 (structural), 1 mem event
        assert_eq!(inner.inst, 40);
        assert_eq!(inner.mem, 1);
    }

    #[test]
    fn fanout_reaches_all_sinks() {
        let mut a = Count::default();
        let mut b = Count::default();
        {
            let mut fan = FanoutSink::new(vec![&mut a, &mut b]);
            let ctx = GroupCtx { group_id: 0 };
            fan.on_inst(&ctx, InstClass::ValuArith, 10);
            fan.on_mem(&ctx, &MemAccess::contiguous(MemKind::Read, 0, 32, 4));
        }
        assert_eq!(a.inst, 10);
        assert_eq!(b.inst, 10);
        assert_eq!(a.mem, 1);
        assert_eq!(b.mem, 1);
    }
}
