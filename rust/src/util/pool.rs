//! A persistent worker pool with help-while-waiting scheduling.
//!
//! The replay engine dispatches thousands of short parallel phases per
//! sweep; spawning OS threads per batch (`std::thread::scope`) costs
//! more than the work itself once dispatches shrink below ~64k events.
//! This pool spawns its workers **once** (see [`WorkerPool::global`])
//! and feeds them jobs from a shared queue:
//!
//! * [`WorkerPool::scope`] — structured fork/join over borrowed data,
//!   the drop-in replacement for `thread::scope`. The calling thread
//!   *helps* (executes queued jobs) instead of blocking, so nested
//!   scopes — an experiment job whose replay engine forks its own L1
//!   phase — cannot starve the pool.
//! * [`WorkerPool::submit`] + [`WorkerPool::wait`] — fire-and-forget
//!   jobs tracked by a [`Latch`], used for pipelined phases that
//!   outlive the call that launched them (the replay engine overlaps
//!   batch N's L2 phase with batch N+1's L1 phase this way).
//!
//! Panics inside jobs are caught, recorded on the latch, and re-raised
//! on the waiting thread **with the first job's original payload**
//! (`resume_unwind`), so the failure surfaces once, with its real
//! message — not as a generic wrapper, and not as a cascade of
//! `PoisonError` unwraps from every lock the dead job left behind.
//! All pool-internal locks recover from poison ([`lock_recover`]):
//! their invariants are re-established by the surrounding logic, and
//! masking the *first* panic with a secondary one is strictly worse.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A panic payload captured from a failed job.
type Payload = Box<dyn std::any::Any + Send + 'static>;

/// Lock `m`, recovering the guard if a panicking thread poisoned it.
///
/// Poisoning exists to warn that a critical section may have been cut
/// short; here the first panic is already captured and re-raised
/// exactly once (by [`WorkerPool::wait`]), so letting every later
/// `lock().unwrap()` blow up as well only buries the real failure
/// under opaque `PoisonError` noise — one worker's death must not
/// cascade across the pool. Shared state guarded this way must
/// tolerate a torn critical section (the pool's queue/latch state
/// does; the replay engine's L2 stage documents its own contract).
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Worker count for the global pool (and the replay engine's default
/// shard count): the host's cores, bounded so tiny machines and huge
/// ones both behave.
pub fn default_threads() -> usize {
    thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 16)
}

/// Completion tracker for a group of pool jobs. Cloning shares the
/// underlying counter (jobs hold a clone while they run).
#[derive(Clone, Default)]
pub struct Latch {
    inner: Arc<LatchInner>,
}

#[derive(Default)]
struct LatchInner {
    pending: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
    /// The **first** failed job's panic payload, re-raised by the
    /// waiter; later failures keep only the flag (their payloads are
    /// dropped — one cause, reported once, beats a cascade).
    payload: Mutex<Option<Payload>>,
}

impl Latch {
    pub fn new() -> Latch {
        Latch::default()
    }

    fn add(&self, n: usize) {
        *lock_recover(&self.inner.pending) += n;
    }

    fn complete(&self, panicked: Option<Payload>) {
        if let Some(payload) = panicked {
            let mut slot = lock_recover(&self.inner.payload);
            if slot.is_none() {
                *slot = Some(payload);
            }
            drop(slot);
            self.inner.panicked.store(true, Ordering::Relaxed);
        }
        let mut pending = lock_recover(&self.inner.pending);
        *pending -= 1;
        if *pending == 0 {
            self.inner.done.notify_all();
        }
    }

    /// All jobs attached so far have finished.
    pub fn is_done(&self) -> bool {
        *lock_recover(&self.inner.pending) == 0
    }

    /// Two handles track the same completion group.
    fn same(&self, other: &Latch) -> bool {
        Arc::ptr_eq(&self.inner, &other.inner)
    }

    fn wait_timeout(&self, d: Duration) {
        let pending = lock_recover(&self.inner.pending);
        if *pending != 0 {
            let _ = match self.inner.done.wait_timeout(pending, d) {
                Ok(r) => r,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    fn panicked(&self) -> bool {
        self.inner.panicked.load(Ordering::Relaxed)
    }

    /// Take the first panic payload (subsequent calls get `None`).
    fn take_payload(&self) -> Option<Payload> {
        lock_recover(&self.inner.payload).take()
    }
}

struct Shared {
    queue: Mutex<VecDeque<(Latch, Job)>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// A fixed set of long-lived worker threads plus a shared FIFO job
/// queue. See the module docs for the two usage shapes.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("rocline-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// The process-wide pool every engine and coordinator shares
    /// (lazily spawned, [`default_threads`] workers, never torn down).
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| WorkerPool::new(default_threads()))
    }

    pub fn worker_count(&self) -> usize {
        self.handles.len()
    }

    fn push(&self, latch: &Latch, job: Job) {
        // propagate the spawner's open span to whichever worker runs
        // the job (None — and no extra box — when obs is off)
        let job = match crate::obs::SpanCtx::capture() {
            Some(ctx) => Box::new(move || {
                let _g = ctx.apply();
                job();
            }) as Job,
            None => job,
        };
        latch.add(1);
        let mut queue = lock_recover(&self.shared.queue);
        queue.push_back((latch.clone(), job));
        drop(queue);
        self.shared.available.notify_one();
    }

    /// Enqueue an owned job tracked by `latch`. Returns immediately;
    /// pair with [`WorkerPool::wait`].
    pub fn submit(&self, latch: &Latch, job: impl FnOnce() + Send + 'static) {
        self.push(latch, Box::new(job));
    }

    /// Pop and run one queued job. When `only` is given, run only a
    /// job attached to that latch: a waiter that grabbed an arbitrary
    /// job could inline minutes of unrelated work (a whole experiment)
    /// after its own microsecond-scale jobs already finished, stalling
    /// the pipeline that is waiting on it. Restricting help to the
    /// awaited latch keeps waits proportional to their own work, and
    /// deadlock-freedom is preserved: a waited latch's jobs are either
    /// queued (the waiter runs them here) or already running on a
    /// thread that likewise helps its own waits.
    fn try_run_one(&self, only: Option<&Latch>) -> bool {
        let job = {
            let mut queue = lock_recover(&self.shared.queue);
            match only {
                None => queue.pop_front(),
                Some(target) => queue
                    .iter()
                    .position(|(l, _)| l.same(target))
                    .and_then(|i| queue.remove(i)),
            }
        };
        match job {
            Some((latch, f)) => {
                run_job(&latch, f);
                true
            }
            None => false,
        }
    }

    fn wait_impl(&self, latch: &Latch) {
        while !latch.is_done() {
            if !self.try_run_one(Some(latch)) {
                // nothing runnable for this latch: its jobs are in
                // flight elsewhere — sleep briefly (latch completion
                // notifies, so the timeout only bounds lost wakeups)
                latch.wait_timeout(Duration::from_millis(1));
            }
        }
    }

    /// Block until every job on `latch` finished, executing queued jobs
    /// while waiting. If any job attached to the latch panicked, the
    /// **first** failure's payload is re-raised here (`resume_unwind`),
    /// so the waiter reports the original panic message exactly once.
    pub fn wait(&self, latch: &Latch) {
        self.wait_impl(latch);
        if latch.panicked() {
            match latch.take_payload() {
                Some(payload) => resume_unwind(payload),
                // payload already re-raised by another waiter of the
                // same latch; still fail this one, loudly
                None => panic!(
                    "worker pool job panicked (first failure \
                     re-raised at another waiter)"
                ),
            }
        }
    }

    /// Structured fork/join over borrowed data: jobs spawned on the
    /// scope may borrow anything that outlives the `scope` call; every
    /// job completes (or the calling thread re-raises its panic) before
    /// `scope` returns.
    pub fn scope<'pool, 'scope, F, R>(&'pool self, f: F) -> R
    where
        F: FnOnce(&PoolScope<'pool, 'scope>) -> R,
    {
        let scope = PoolScope {
            pool: self,
            latch: Latch::new(),
            _env: PhantomData,
        };
        // the guard waits out still-borrowing jobs even if `f` panics
        let mut guard = ScopeGuard {
            pool: self,
            latch: scope.latch.clone(),
            armed: true,
        };
        let r = f(&scope);
        guard.armed = false;
        self.wait(&scope.latch);
        r
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

struct ScopeGuard<'a> {
    pool: &'a WorkerPool,
    latch: Latch,
    armed: bool,
}

impl Drop for ScopeGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // unwinding out of `scope`: jobs may still borrow the
            // caller's frame, so finish them before it goes away
            self.pool.wait_impl(&self.latch);
        }
    }
}

/// Spawn handle passed to the closure of [`WorkerPool::scope`].
pub struct PoolScope<'pool, 'scope> {
    pool: &'pool WorkerPool,
    latch: Latch,
    _env: PhantomData<&'scope mut &'scope ()>,
}

impl<'pool, 'scope> PoolScope<'pool, 'scope> {
    pub fn spawn<F: FnOnce() + Send + 'scope>(&self, f: F) {
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(f);
        // SAFETY: `WorkerPool::scope` (and its unwind guard) waits for
        // every job on this scope's latch before control returns to the
        // caller, so the job never outlives the 'scope borrows it
        // captured; erasing the lifetime for the queue is then sound.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(
                job,
            )
        };
        self.pool.push(&self.latch, job);
    }
}

/// One value computed ahead of time on the global pool — the
/// pipelining primitive behind the streaming replay's decode-ahead
/// stage (decode dispatch N+1 while dispatch N replays, mirroring the
/// engine's L1/L2 double buffer).
///
/// [`Prefetch::spawn`] enqueues the job and returns immediately;
/// [`Prefetch::join`] blocks (helping the pool meanwhile, per
/// [`WorkerPool::wait`]) and takes the result. A panicking job
/// re-raises its original payload at `join`.
pub struct Prefetch<T> {
    latch: Latch,
    slot: Arc<Mutex<Option<T>>>,
}

impl<T: Send + 'static> Prefetch<T> {
    pub fn spawn(f: impl FnOnce() -> T + Send + 'static) -> Prefetch<T> {
        let latch = Latch::new();
        let slot = Arc::new(Mutex::new(None));
        let out = Arc::clone(&slot);
        WorkerPool::global().submit(&latch, move || {
            let v = f();
            *lock_recover(&out) = Some(v);
        });
        Prefetch { latch, slot }
    }

    /// Wait out the job and take its value.
    pub fn join(self) -> T {
        WorkerPool::global().wait(&self.latch);
        lock_recover(&self.slot)
            .take()
            .expect("prefetch job finished without storing a result")
    }
}

/// Why a [`CancelToken::checkpoint`] failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cancelled {
    /// Someone called [`CancelToken::cancel`].
    Explicit,
    /// The token's deadline passed.
    DeadlineExpired,
}

impl std::fmt::Display for Cancelled {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Cancelled::Explicit => f.write_str("job cancelled"),
            Cancelled::DeadlineExpired => {
                f.write_str("job deadline expired")
            }
        }
    }
}

impl std::error::Error for Cancelled {}

/// Cooperative cancellation (with an optional deadline) for long
/// replay jobs scheduled on the pool. The replay engine's dispatch
/// loops call [`CancelToken::checkpoint`] between dispatches; a
/// cancelled or deadline-expired job unwinds cleanly at the next
/// checkpoint instead of running to completion — the hook the
/// analysis service's per-request deadlines and `cancel` endpoint
/// are built on. Clones share the same state (the job holds one
/// clone, the canceller another).
#[derive(Clone, Default)]
pub struct CancelToken {
    inner: Arc<CancelState>,
}

#[derive(Default)]
struct CancelState {
    cancelled: AtomicBool,
    deadline: Mutex<Option<std::time::Instant>>,
}

impl CancelToken {
    /// A token that never fires until [`CancelToken::cancel`] is
    /// called.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// A token that additionally expires at `deadline`.
    pub fn with_deadline(deadline: std::time::Instant) -> CancelToken {
        let t = CancelToken::new();
        *lock_recover(&t.inner.deadline) = Some(deadline);
        t
    }

    /// Request cancellation: every checkpoint from now on fails with
    /// [`Cancelled::Explicit`].
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Relaxed);
    }

    /// The deadline this token expires at, if any.
    pub fn deadline(&self) -> Option<std::time::Instant> {
        *lock_recover(&self.inner.deadline)
    }

    /// Whether the next checkpoint would fail (explicit cancel *or*
    /// expired deadline).
    pub fn is_cancelled(&self) -> bool {
        self.checkpoint().is_err()
    }

    /// The cooperative cancellation point: cheap enough to call once
    /// per dispatch. Explicit cancellation wins over a deadline that
    /// has also passed (the caller asked first).
    pub fn checkpoint(&self) -> Result<(), Cancelled> {
        if self.inner.cancelled.load(Ordering::Relaxed) {
            return Err(Cancelled::Explicit);
        }
        if let Some(d) = *lock_recover(&self.inner.deadline) {
            if std::time::Instant::now() >= d {
                return Err(Cancelled::DeadlineExpired);
            }
        }
        Ok(())
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut queue = lock_recover(&shared.queue);
            loop {
                if let Some(j) = queue.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Relaxed) {
                    break None;
                }
                queue = match shared.available.wait(queue) {
                    Ok(q) => q,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
        };
        match job {
            Some((latch, f)) => run_job(&latch, f),
            None => return,
        }
    }
}

fn run_job(latch: &Latch, f: Job) {
    latch.complete(
        catch_unwind(AssertUnwindSafe(|| {
            if crate::fault::should_fail("pool.job_panic") {
                panic!("injected fault at pool.job_panic");
            }
            f()
        }))
        .err(),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scope_runs_every_job() {
        let pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..64 {
                s.spawn(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn scope_jobs_borrow_mutably_and_disjointly() {
        let pool = WorkerPool::new(2);
        let mut data = vec![0u64; 8];
        pool.scope(|s| {
            for (i, slot) in data.iter_mut().enumerate() {
                s.spawn(move || {
                    *slot = i as u64 + 1;
                });
            }
        });
        assert_eq!(data, vec![1, 2, 3, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // outer jobs occupy workers and fork inner scopes; the
        // help-while-waiting loop must keep everything moving even on
        // a single-worker pool
        let pool = WorkerPool::new(1);
        let counter = AtomicUsize::new(0);
        pool.scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    WorkerPool::global().scope(|inner| {
                        for _ in 0..4 {
                            inner.spawn(|| {
                                counter.fetch_add(1, Ordering::Relaxed);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn submit_and_wait_round_trip() {
        let pool = WorkerPool::new(2);
        let flag = Arc::new(AtomicBool::new(false));
        let latch = Latch::new();
        let f = Arc::clone(&flag);
        pool.submit(&latch, move || {
            f.store(true, Ordering::Relaxed);
        });
        pool.wait(&latch);
        assert!(flag.load(Ordering::Relaxed));
        assert!(latch.is_done());
    }

    #[test]
    fn waiting_thread_helps_run_jobs() {
        // even with zero spare workers (all asleep on an empty queue,
        // then flooded), wait() itself must make progress
        let pool = WorkerPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        let latch = Latch::new();
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            pool.submit(&latch, move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait(&latch);
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn job_panics_propagate_the_original_payload() {
        // regression: the waiter used to panic with a generic
        // "worker pool job panicked", losing the real failure message
        let pool = WorkerPool::new(2);
        pool.scope(|s| {
            s.spawn(|| panic!("boom"));
        });
    }

    #[test]
    fn first_panic_wins_and_the_pool_stays_usable() {
        let pool = WorkerPool::new(2);
        // several failing jobs: exactly the first recorded payload is
        // re-raised (the others only keep the flag)
        let latch = Latch::new();
        for i in 0..4 {
            pool.submit(&latch, move || {
                panic!("job {i} failed");
            });
        }
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.wait(&latch);
        }))
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("failed"), "original payload: {msg}");

        // regression: a panicked job must not cascade — the pool's
        // internal locks recover from poison and later jobs run fine
        let counter = Arc::new(AtomicUsize::new(0));
        let latch2 = Latch::new();
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.submit(&latch2, move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait(&latch2);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn lock_recover_survives_a_poisoning_panic() {
        // regression: shared engine state (`memsim/sharded.rs`'s L2
        // stage) used `lock().unwrap()`, so one panicking job holding
        // the lock turned every later access into an opaque secondary
        // PoisonError panic
        let pool = WorkerPool::new(2);
        let stage = Arc::new(Mutex::new(7u64));
        let poisoner = Arc::clone(&stage);
        let latch = Latch::new();
        pool.submit(&latch, move || {
            let _guard = poisoner.lock().unwrap();
            panic!("died holding the stage lock");
        });
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.wait(&latch);
        }))
        .unwrap_err();
        assert!(err
            .downcast_ref::<&str>()
            .is_some_and(|m| m.contains("stage lock")));
        assert!(stage.is_poisoned(), "precondition: lock poisoned");
        // the recovering accessor still reads (and can repair) state
        assert_eq!(*lock_recover(&stage), 7);
        *lock_recover(&stage) = 8;
        assert_eq!(*lock_recover(&stage), 8);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let a = WorkerPool::global();
        let b = WorkerPool::global();
        assert!(std::ptr::eq(a, b));
        assert_eq!(a.worker_count(), default_threads());
    }

    #[test]
    fn prefetch_returns_its_value() {
        let p = Prefetch::spawn(|| 6u64 * 7);
        assert_eq!(p.join(), 42);
    }

    #[test]
    fn prefetch_pipeline_overlaps_and_stays_ordered() {
        // the decode-ahead shape: spawn N+1 before consuming N; every
        // value arrives, in order, regardless of scheduling
        let mut pending = Prefetch::spawn(move || 0u64);
        let mut seen = Vec::new();
        for next in 1..16u64 {
            let p = Prefetch::spawn(move || next);
            seen.push(pending.join());
            pending = p;
        }
        seen.push(pending.join());
        assert_eq!(seen, (0..16).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "decode job failed")]
    fn prefetch_panics_propagate_at_join() {
        let p: Prefetch<u64> =
            Prefetch::spawn(|| panic!("decode job failed"));
        let _ = p.join();
    }

    #[test]
    fn cancel_token_default_passes_checkpoints() {
        let t = CancelToken::new();
        assert!(!t.is_cancelled());
        assert!(t.checkpoint().is_ok());
        assert!(t.deadline().is_none());
    }

    #[test]
    fn cancel_token_explicit_cancel_fires() {
        let t = CancelToken::new();
        let shared = t.clone();
        shared.cancel();
        assert_eq!(t.checkpoint(), Err(Cancelled::Explicit));
        assert!(t.is_cancelled());
    }

    #[test]
    fn cancel_token_deadline_expires() {
        let past = std::time::Instant::now();
        let t = CancelToken::with_deadline(past);
        assert_eq!(t.checkpoint(), Err(Cancelled::DeadlineExpired));
        let future = std::time::Instant::now()
            + Duration::from_secs(3600);
        let t = CancelToken::with_deadline(future);
        assert!(t.checkpoint().is_ok());
        assert_eq!(t.deadline(), Some(future));
        // explicit cancellation wins over an expired deadline
        let t = CancelToken::with_deadline(past);
        t.cancel();
        assert_eq!(t.checkpoint(), Err(Cancelled::Explicit));
    }

    #[test]
    fn cancelled_renders_and_is_an_error() {
        let e: Box<dyn std::error::Error> =
            Box::new(Cancelled::DeadlineExpired);
        assert!(e.to_string().contains("deadline"));
        assert!(Cancelled::Explicit.to_string().contains("cancelled"));
    }

    #[test]
    fn sequential_order_preserved_by_chained_latches() {
        // the pipelining pattern: phase N+1 is only submitted after
        // phase N's latch is waited, so effects serialize
        let pool = WorkerPool::new(4);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..8 {
            let latch = Latch::new();
            let l = Arc::clone(&log);
            pool.submit(&latch, move || {
                l.lock().unwrap().push(i);
            });
            pool.wait(&latch);
        }
        assert_eq!(*log.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }
}
