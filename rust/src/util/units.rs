//! Unit helpers: bytes, bandwidths, frequencies, durations.
//!
//! The paper mixes MB/s (BabelStream output), GB/s (roofline axes), KB
//! (rocProf `FETCH_SIZE`/`WRITE_SIZE`) and GHz; these newtypes keep the
//! conversions in one audited place.

/// Bytes per rocProf `FETCH_SIZE`/`WRITE_SIZE` unit (the counter is in KB).
pub const ROCPROF_KB: f64 = 1024.0;

/// Size of one memory transaction in the NVIDIA instruction roofline
/// (Ding & Williams 2019): a 32-byte sector.
pub const SECTOR_BYTES: u64 = 32;

/// Gibi/Giga constants.
pub const GIGA: f64 = 1.0e9;
pub const KIB: f64 = 1024.0;
pub const MIB: f64 = 1024.0 * 1024.0;
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Bandwidth in bytes/second. Stored as f64 bytes/s.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Bandwidth(pub f64);

impl Bandwidth {
    pub fn from_gbs(gbs: f64) -> Self {
        Bandwidth(gbs * GIGA)
    }
    /// BabelStream reports decimal MB/s.
    pub fn from_mbs(mbs: f64) -> Self {
        Bandwidth(mbs * 1.0e6)
    }
    pub fn gbs(self) -> f64 {
        self.0 / GIGA
    }
    pub fn mbs(self) -> f64 {
        self.0 / 1.0e6
    }
    /// Transactions/second at 32B sectors, in billions (GTXN/s).
    pub fn gtxn_s(self) -> f64 {
        self.0 / SECTOR_BYTES as f64 / GIGA
    }
    pub fn scale(self, f: f64) -> Self {
        Bandwidth(self.0 * f)
    }
}

/// Duration in seconds (f64 keeps the math simple; precision is ample).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Seconds(pub f64);

impl Seconds {
    pub fn from_ns(ns: f64) -> Self {
        Seconds(ns * 1.0e-9)
    }
    pub fn from_us(us: f64) -> Self {
        Seconds(us * 1.0e-6)
    }
    pub fn ns(self) -> f64 {
        self.0 * 1.0e9
    }
    pub fn us(self) -> f64 {
        self.0 * 1.0e6
    }
    pub fn ms(self) -> f64 {
        self.0 * 1.0e3
    }
}

impl std::ops::Add for Seconds {
    type Output = Seconds;
    fn add(self, rhs: Seconds) -> Seconds {
        Seconds(self.0 + rhs.0)
    }
}

impl std::iter::Sum for Seconds {
    fn sum<I: Iterator<Item = Seconds>>(iter: I) -> Seconds {
        Seconds(iter.map(|s| s.0).sum())
    }
}

/// Format a byte count with binary suffix for reports.
pub fn human_bytes(b: u64) -> String {
    let bf = b as f64;
    if bf >= GIB {
        format!("{:.2} GiB", bf / GIB)
    } else if bf >= MIB {
        format!("{:.2} MiB", bf / MIB)
    } else if bf >= KIB {
        format!("{:.2} KiB", bf / KIB)
    } else {
        format!("{b} B")
    }
}

/// Format a count with thousands separators (paper tables use them).
pub fn group_digits(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    let bytes = s.as_bytes();
    for (i, c) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*c as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_conversions() {
        let bw = Bandwidth::from_gbs(900.0);
        assert!((bw.gbs() - 900.0).abs() < 1e-12);
        assert!((bw.mbs() - 900_000.0).abs() < 1e-9);
        // 900 GB/s over 32B sectors = 28.125 GTXN/s
        assert!((bw.gtxn_s() - 28.125).abs() < 1e-12);
    }

    #[test]
    fn babelstream_mbs_roundtrip() {
        // the paper's MI60 copy rate
        let bw = Bandwidth::from_mbs(808_975.476);
        assert!((bw.gbs() - 808.975476).abs() < 1e-9);
    }

    #[test]
    fn seconds_conversions() {
        let t = Seconds::from_us(2.5);
        assert!((t.ns() - 2500.0).abs() < 1e-9);
        assert!((t.ms() - 0.0025).abs() < 1e-12);
        let sum: Seconds = vec![Seconds(0.5), Seconds(0.25)].into_iter().sum();
        assert!((sum.0 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn human_bytes_suffixes() {
        assert_eq!(human_bytes(10), "10 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(human_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn group_digits_matches_paper_style() {
        assert_eq!(group_digits(449_796_480), "449,796,480");
        assert_eq!(group_digits(0), "0");
        assert_eq!(group_digits(999), "999");
        assert_eq!(group_digits(1000), "1,000");
    }
}
