//! Miniature property-testing framework (`proptest` is unavailable
//! offline).
//!
//! A property is a closure from a generated case to `Result<(), String>`.
//! [`Checker::run`] executes it over many deterministic random cases and,
//! on failure, reports the seed and iteration so the case can be replayed
//! exactly. Generators compose through plain closures over
//! [`crate::util::Xoshiro256`].
//!
//! Usage:
//! ```
//! use rocline::util::check::{Checker, prop_assert};
//! Checker::new("addition commutes").cases(200).run(|rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     prop_assert(a + b == b + a, || format!("{a} {b}"))
//! });
//! ```

use crate::util::rng::Xoshiro256;

pub struct Checker {
    name: String,
    cases: u32,
    seed: u64,
}

impl Checker {
    pub fn new(name: &str) -> Self {
        let seed = std::env::var("ROCLINE_CHECK_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xD1CE_5EED);
        Checker {
            name: name.to_string(),
            cases: 100,
            seed,
        }
    }

    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run the property; panics with a replayable report on failure.
    pub fn run<F>(self, mut prop: F)
    where
        F: FnMut(&mut Xoshiro256) -> Result<(), String>,
    {
        for i in 0..self.cases {
            // Each case gets an independent stream: replaying case i does
            // not require regenerating cases 0..i-1.
            let case_seed = self.seed.wrapping_add(i as u64);
            let mut rng = Xoshiro256::seed_from_u64(case_seed);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "property '{}' failed at case {}/{} \
                     (replay: ROCLINE_CHECK_SEED={} case offset {}):\n  {}",
                    self.name, i, self.cases, self.seed, i, msg
                );
            }
        }
    }
}

/// Assert helper returning `Result` for use inside properties.
pub fn prop_assert<F: FnOnce() -> String>(
    cond: bool,
    msg: F,
) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

/// Approximate float equality for properties.
pub fn approx_eq(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Checker::new("counts").cases(50).run(|_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_name() {
        Checker::new("fails").cases(10).run(|rng| {
            let x = rng.below(100);
            prop_assert(x < 90, || format!("x={x}"))
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first: Vec<u64> = Vec::new();
        Checker::new("a").cases(5).seed(99).run(|rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        Checker::new("b").cases(5).seed(99).run(|rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn approx_eq_behaviour() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(approx_eq(0.0, 1e-12, 0.0, 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-3, 1e-3));
    }
}
