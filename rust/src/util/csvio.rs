//! Minimal CSV reading/writing (offline environment: no `csv` crate).
//!
//! Used for rocprof-sim/nvprof-sim output (the real rocProf emits CSV) and
//! for the per-figure data series the plots are built from.

use std::io::Write;
use std::path::Path;

/// Write rows as CSV. Cells are escaped with quotes when they contain
/// commas or quotes (rocprof kernel names can contain templated commas).
pub fn write_csv<P: AsRef<Path>>(
    path: P,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|c| escape(c)).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

pub fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Parse one CSV line honouring double-quote escapes.
pub fn parse_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    out.push(cur);
    out
}

/// Read a whole CSV file into (header, rows).
pub fn read_csv<P: AsRef<Path>>(
    path: P,
) -> std::io::Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(path)?;
    let mut lines = text.lines();
    let header = lines.next().map(parse_line).unwrap_or_default();
    let rows = lines.map(parse_line).collect();
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        assert_eq!(parse_line("a,b,c"), vec!["a", "b", "c"]);
    }

    #[test]
    fn roundtrip_quoted() {
        let cell = "Kernel<foo, bar>";
        let esc = escape(cell);
        assert_eq!(esc, "\"Kernel<foo, bar>\"");
        assert_eq!(parse_line(&format!("x,{esc},y")), vec!["x", cell, "y"]);
    }

    #[test]
    fn embedded_quotes() {
        let cell = "say \"hi\"";
        let esc = escape(cell);
        assert_eq!(parse_line(&esc), vec![cell]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("rocline_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        write_csv(
            &p,
            &["a", "b"],
            &[vec!["1".into(), "x,y".into()], vec!["2".into(), "z".into()]],
        )
        .unwrap();
        let (h, rows) = read_csv(&p).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(rows[0], vec!["1", "x,y"]);
        assert_eq!(rows[1], vec!["2", "z"]);
    }

    #[test]
    fn empty_cells() {
        assert_eq!(parse_line("a,,c"), vec!["a", "", "c"]);
    }
}
