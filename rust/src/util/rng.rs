//! Deterministic RNG (xoshiro256**) — no `rand` crate offline.
//!
//! Every stochastic path in the toolkit (workload initialization, synthetic
//! traces, property tests) derives from this generator with explicit seeds,
//! so runs are bit-reproducible.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that nearby seeds give uncorrelated streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Xoshiro256 {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire rejection-free approximation is
    /// overkill here; modulo bias is negligible for our n ≪ 2^32).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Xoshiro256::seed_from_u64(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
