//! Shared utilities: units, deterministic RNG, statistics, tables, CSV,
//! a bench harness and a miniature property-testing framework.
//!
//! The crate registry is offline in this environment, so the usual
//! ecosystem crates (`criterion`, `proptest`, `serde`) are replaced by the
//! small, purpose-built modules here (see DESIGN.md §2).

pub mod bench;
pub mod check;
pub mod csvio;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;

pub use pool::{CancelToken, Cancelled, WorkerPool};
pub use rng::Xoshiro256;
pub use stats::Summary;
