//! Small statistics helpers for benchmark and profiling summaries.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
        }
    }

    /// Relative std (coefficient of variation); 0 when mean == 0.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean.abs()
        }
    }
}

/// Online mean/max accumulator for streaming pipelines (no allocation).
#[derive(Debug, Clone, Copy, Default)]
pub struct Running {
    pub n: u64,
    pub sum: f64,
    pub max: f64,
    pub min: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            sum: 0.0,
            max: f64::NEG_INFINITY,
            min: f64::INFINITY,
        }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < self.min {
            self.min = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

/// Geometric mean (used for cross-kernel speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let logsum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values");
            x.ln()
        })
        .sum();
    (logsum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        // sample std of 1,2,3,4 = sqrt(5/3)
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn odd_median() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn running_accumulator() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 6.0] {
            r.push(x);
        }
        assert_eq!(r.n, 3);
        assert!((r.mean() - 4.0).abs() < 1e-12);
        assert_eq!(r.max, 6.0);
        assert_eq!(r.min, 2.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }
}
