//! ASCII/Markdown table rendering for paper-style report output.
//!
//! The `reproduce` experiments print rows in the same layout as the paper's
//! Tables 1 and 2; this module owns the formatting.

/// A simple column-aligned table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn cell(&self, row: usize, col: usize) -> &str {
        &self.rows[row][col]
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Fixed-width ASCII rendering (first column left-aligned, rest right).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = w[i]));
                } else {
                    line.push_str(&format!("{:>width$}", c, width = w[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavoured Markdown rendering (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.header.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// CSV rendering (no quoting needed: our cells never contain commas).
    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float the way the paper does: 3 decimals, or scientific for
/// very small magnitudes.
pub fn paper_f64(x: f64) -> String {
    if x == 0.0 {
        "0.000".to_string()
    } else if x.abs() < 0.0005 {
        format!("{x:.1e}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(vec!["GPU", "Peak GIPS"]);
        t.row(vec!["V100", "489.60"]);
        t.row(vec!["MI60", "115.20"]);
        t
    }

    #[test]
    fn render_aligns_columns() {
        let s = sample().render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("GPU"));
        assert!(lines[2].contains("489.60"));
        // right alignment: both numeric cells end at same column
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn markdown_shape() {
        let md = sample().render_markdown();
        assert!(md.starts_with("| GPU | Peak GIPS |\n|---|---|\n"));
        assert!(md.contains("| MI60 | 115.20 |"));
    }

    #[test]
    fn csv_shape() {
        let csv = sample().render_csv();
        assert_eq!(csv.lines().count(), 3);
        assert_eq!(csv.lines().next().unwrap(), "GPU,Peak GIPS");
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn paper_float_format() {
        assert_eq!(paper_f64(0.0040), "0.004");
        assert_eq!(paper_f64(2.856), "2.856");
        assert_eq!(paper_f64(489.6), "489.600");
        assert_eq!(paper_f64(0.0), "0.000");
        assert!(paper_f64(0.0001).contains('e'));
    }
}
