//! Custom micro-benchmark harness (`criterion` is unavailable offline).
//!
//! `cargo bench` binaries (`rust/benches/*.rs`, `harness = false`) build a
//! [`BenchRunner`], register closures, and get a criterion-style report:
//! warmup, fixed sample count, mean ± σ, min, and throughput when an item
//! count is given. Set `ROCLINE_BENCH_FAST=1` to shrink samples for CI.

use std::time::Instant;

use crate::util::stats::Summary;

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub samples: u32,
    /// Each sample runs the closure `iters_per_sample` times and divides.
    pub iters_per_sample: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if std::env::var("ROCLINE_BENCH_FAST").is_ok() {
            BenchConfig {
                warmup_iters: 1,
                samples: 5,
                iters_per_sample: 1,
            }
        } else {
            BenchConfig {
                warmup_iters: 3,
                samples: 20,
                iters_per_sample: 1,
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub time: Summary,
    /// Items/second if `throughput_items` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    /// Operations per second: the registered item throughput, or the
    /// iteration rate when the bench had no item count.
    pub fn ops_per_sec(&self) -> f64 {
        self.throughput.unwrap_or_else(|| {
            if self.time.mean > 0.0 {
                1.0 / self.time.mean
            } else {
                0.0
            }
        })
    }

    pub fn report_line(&self) -> String {
        let mean = self.time.mean;
        let (scale, unit) = if mean < 1e-6 {
            (1e9, "ns")
        } else if mean < 1e-3 {
            (1e6, "µs")
        } else if mean < 1.0 {
            (1e3, "ms")
        } else {
            (1.0, "s")
        };
        let mut line = format!(
            "{:<44} {:>10.3} {unit} ± {:>8.3} {unit}  (min {:>10.3} {unit})",
            self.name,
            mean * scale,
            self.time.std * scale,
            self.time.min * scale,
        );
        if let Some(tp) = self.throughput {
            if tp >= 1e9 {
                line.push_str(&format!("  {:>8.2} Gelem/s", tp / 1e9));
            } else if tp >= 1e6 {
                line.push_str(&format!("  {:>8.2} Melem/s", tp / 1e6));
            } else {
                line.push_str(&format!("  {tp:>8.0} elem/s"));
            }
        }
        line
    }
}

pub struct BenchRunner {
    config: BenchConfig,
    results: Vec<BenchResult>,
    group: String,
}

impl BenchRunner {
    pub fn new(group: &str) -> Self {
        println!("== bench group: {group} ==");
        BenchRunner {
            config: BenchConfig::default(),
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Run `f` and record timing. `f` should return something observable to
    /// keep the optimizer honest; its return value is black-boxed.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) {
        self.bench_items(name, None, &mut f);
    }

    /// Like [`bench`], with items/second throughput reporting.
    pub fn bench_throughput<R, F: FnMut() -> R>(
        &mut self,
        name: &str,
        items: u64,
        mut f: F,
    ) {
        self.bench_items(name, Some(items), &mut f);
    }

    fn bench_items<R>(
        &mut self,
        name: &str,
        items: Option<u64>,
        f: &mut dyn FnMut() -> R,
    ) {
        for _ in 0..self.config.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.config.samples as usize);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..self.config.iters_per_sample {
                std::hint::black_box(f());
            }
            times.push(
                t0.elapsed().as_secs_f64()
                    / self.config.iters_per_sample as f64,
            );
        }
        let time = Summary::of(&times);
        let throughput = items.map(|n| n as f64 / time.mean);
        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            time,
            throughput,
        };
        println!("{}", result.report_line());
        self.results.push(result);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(self) -> Vec<BenchResult> {
        println!();
        self.results
    }
}

/// Serialize results as a flat `{"name": ops_per_sec}` JSON object —
/// the machine-readable artifact CI diffs (`serde` is unavailable
/// offline; the format is simple enough to emit by hand).
pub fn results_to_json(results: &[BenchResult]) -> String {
    let mut out = String::from("{\n");
    for (i, r) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        // bench names are path-like ASCII (group/case); escape the
        // quote/backslash anyway so the artifact is always valid JSON
        let name: String = r
            .name
            .chars()
            .flat_map(|c| match c {
                '"' => vec!['\\', '"'],
                '\\' => vec!['\\', '\\'],
                c => vec![c],
            })
            .collect();
        out.push_str(&format!(
            "  \"{}\": {:.3}{}\n",
            name,
            r.ops_per_sec(),
            comma
        ));
    }
    out.push_str("}\n");
    out
}

/// Write [`results_to_json`] to `path`.
pub fn write_json(
    results: &[BenchResult],
    path: &std::path::Path,
) -> std::io::Result<()> {
    std::fs::write(path, results_to_json(results))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> BenchConfig {
        BenchConfig {
            warmup_iters: 1,
            samples: 3,
            iters_per_sample: 2,
        }
    }

    #[test]
    fn records_results() {
        let mut r = BenchRunner::new("test").with_config(fast());
        r.bench("noop", || 1 + 1);
        r.bench_throughput("sum", 1000, || (0..1000u64).sum::<u64>());
        let results = r.finish();
        assert_eq!(results.len(), 2);
        assert!(results[0].time.mean >= 0.0);
        assert!(results[1].throughput.unwrap() > 0.0);
    }

    #[test]
    fn json_artifact_shape() {
        let results = vec![
            BenchResult {
                name: "g/a".into(),
                time: Summary::of(&[0.5, 0.5]),
                throughput: Some(1000.0),
            },
            BenchResult {
                name: "g/b".into(),
                time: Summary::of(&[0.25, 0.25]),
                throughput: None,
            },
        ];
        let json = results_to_json(&results);
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        assert!(json.contains("\"g/a\": 1000.000,"), "{json}");
        assert!(json.contains("\"g/b\": 4.000\n"), "{json}");
    }

    #[test]
    fn report_line_units() {
        let res = BenchResult {
            name: "g/x".into(),
            time: Summary::of(&[2e-9, 2e-9, 2e-9]),
            throughput: Some(5e8),
        };
        let line = res.report_line();
        assert!(line.contains("ns"), "{line}");
        assert!(line.contains("Melem/s"), "{line}");
    }
}
