//! Custom micro-benchmark harness (`criterion` is unavailable offline).
//!
//! `cargo bench` binaries (`rust/benches/*.rs`, `harness = false`) build a
//! [`BenchRunner`], register closures, and get a criterion-style report:
//! warmup, fixed sample count, mean ± σ, min, and throughput when an item
//! count is given. Set `ROCLINE_BENCH_FAST=1` to shrink samples for CI.

use std::time::Instant;

use crate::util::stats::Summary;

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub samples: u32,
    /// Each sample runs the closure `iters_per_sample` times and divides.
    pub iters_per_sample: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if std::env::var("ROCLINE_BENCH_FAST").is_ok() {
            BenchConfig {
                warmup_iters: 1,
                samples: 5,
                iters_per_sample: 1,
            }
        } else {
            BenchConfig {
                warmup_iters: 3,
                samples: 20,
                iters_per_sample: 1,
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time, seconds.
    pub time: Summary,
    /// Items/second if `throughput_items` was set.
    pub throughput: Option<f64>,
}

impl BenchResult {
    /// Operations per second: the registered item throughput, or the
    /// iteration rate when the bench had no item count.
    pub fn ops_per_sec(&self) -> f64 {
        self.throughput.unwrap_or_else(|| {
            if self.time.mean > 0.0 {
                1.0 / self.time.mean
            } else {
                0.0
            }
        })
    }

    pub fn report_line(&self) -> String {
        let mean = self.time.mean;
        let (scale, unit) = if mean < 1e-6 {
            (1e9, "ns")
        } else if mean < 1e-3 {
            (1e6, "µs")
        } else if mean < 1.0 {
            (1e3, "ms")
        } else {
            (1.0, "s")
        };
        let mut line = format!(
            "{:<44} {:>10.3} {unit} ± {:>8.3} {unit}  (min {:>10.3} {unit})",
            self.name,
            mean * scale,
            self.time.std * scale,
            self.time.min * scale,
        );
        if let Some(tp) = self.throughput {
            if tp >= 1e9 {
                line.push_str(&format!("  {:>8.2} Gelem/s", tp / 1e9));
            } else if tp >= 1e6 {
                line.push_str(&format!("  {:>8.2} Melem/s", tp / 1e6));
            } else {
                line.push_str(&format!("  {tp:>8.0} elem/s"));
            }
        }
        line
    }
}

pub struct BenchRunner {
    config: BenchConfig,
    results: Vec<BenchResult>,
    group: String,
}

impl BenchRunner {
    pub fn new(group: &str) -> Self {
        println!("== bench group: {group} ==");
        BenchRunner {
            config: BenchConfig::default(),
            results: Vec::new(),
            group: group.to_string(),
        }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Run `f` and record timing. `f` should return something observable to
    /// keep the optimizer honest; its return value is black-boxed.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, mut f: F) {
        self.bench_items(name, None, &mut f);
    }

    /// Like [`bench`], with items/second throughput reporting.
    pub fn bench_throughput<R, F: FnMut() -> R>(
        &mut self,
        name: &str,
        items: u64,
        mut f: F,
    ) {
        self.bench_items(name, Some(items), &mut f);
    }

    fn bench_items<R>(
        &mut self,
        name: &str,
        items: Option<u64>,
        f: &mut dyn FnMut() -> R,
    ) {
        for _ in 0..self.config.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.config.samples as usize);
        for _ in 0..self.config.samples {
            let t0 = Instant::now();
            for _ in 0..self.config.iters_per_sample {
                std::hint::black_box(f());
            }
            times.push(
                t0.elapsed().as_secs_f64()
                    / self.config.iters_per_sample as f64,
            );
        }
        let time = Summary::of(&times);
        let throughput = items.map(|n| n as f64 / time.mean);
        let result = BenchResult {
            name: format!("{}/{}", self.group, name),
            time,
            throughput,
        };
        println!("{}", result.report_line());
        self.results.push(result);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    pub fn finish(self) -> Vec<BenchResult> {
        println!();
        self.results
    }
}

/// Serialize results as a flat `{"name": ops_per_sec}` JSON object —
/// the machine-readable artifact CI diffs (`serde` is unavailable
/// offline; the format is simple enough to emit by hand).
pub fn results_to_json(results: &[BenchResult]) -> String {
    let mut out = String::from("{\n");
    for (i, r) in results.iter().enumerate() {
        let comma = if i + 1 == results.len() { "" } else { "," };
        // bench names are path-like ASCII (group/case); escape the
        // quote/backslash anyway so the artifact is always valid JSON
        let name: String = r
            .name
            .chars()
            .flat_map(|c| match c {
                '"' => vec!['\\', '"'],
                '\\' => vec!['\\', '\\'],
                c => vec![c],
            })
            .collect();
        out.push_str(&format!(
            "  \"{}\": {:.3}{}\n",
            name,
            r.ops_per_sec(),
            comma
        ));
    }
    out.push_str("}\n");
    out
}

/// Write [`results_to_json`] to `path`.
pub fn write_json(
    results: &[BenchResult],
    path: &std::path::Path,
) -> std::io::Result<()> {
    std::fs::write(path, results_to_json(results))
}

/// Parse the flat `{"name": number}` JSON this module writes (and CI
/// baselines hand-edit). `serde` is unavailable offline; the format is
/// one object of string keys and numeric values, nothing else.
pub fn parse_flat_json(s: &str) -> anyhow::Result<Vec<(String, f64)>> {
    fn skip_ws(it: &mut std::iter::Peekable<std::str::Chars<'_>>) {
        while matches!(it.peek(), Some(c) if c.is_whitespace()) {
            it.next();
        }
    }
    let mut out = Vec::new();
    let mut it = s.chars().peekable();
    skip_ws(&mut it);
    anyhow::ensure!(
        it.next() == Some('{'),
        "flat JSON must start with '{{'"
    );
    loop {
        skip_ws(&mut it);
        match it.peek() {
            Some('}') => {
                it.next();
                break;
            }
            Some('"') => {
                it.next();
                let mut key = String::new();
                loop {
                    match it.next() {
                        Some('\\') => {
                            if let Some(c) = it.next() {
                                key.push(c);
                            }
                        }
                        Some('"') => break,
                        Some(c) => key.push(c),
                        None => anyhow::bail!(
                            "unterminated key in flat JSON"
                        ),
                    }
                }
                skip_ws(&mut it);
                anyhow::ensure!(
                    it.next() == Some(':'),
                    "expected ':' after \"{key}\""
                );
                skip_ws(&mut it);
                let mut num = String::new();
                while matches!(
                    it.peek(),
                    Some(c) if c.is_ascii_digit()
                        || "+-.eE".contains(*c)
                ) {
                    num.push(it.next().unwrap());
                }
                let v: f64 = num.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "bad number '{num}' for \"{key}\""
                    )
                })?;
                out.push((key, v));
                skip_ws(&mut it);
                if it.peek() == Some(&',') {
                    it.next();
                }
            }
            other => {
                anyhow::bail!("unexpected {other:?} in flat JSON")
            }
        }
    }
    Ok(out)
}

/// Render `(name, value)` pairs in the same flat JSON shape as
/// [`results_to_json`] — used to write bench-gate baselines.
pub fn flat_json(pairs: &[(String, f64)]) -> String {
    let mut out = String::from("{\n");
    for (i, (k, v)) in pairs.iter().enumerate() {
        let comma = if i + 1 == pairs.len() { "" } else { "," };
        out.push_str(&format!("  \"{k}\": {v:.3}{comma}\n"));
    }
    out.push_str("}\n");
    out
}

/// Today's UTC date as `YYYY-MM-DD` (no `chrono` offline; civil-date
/// conversion from the unix epoch, Hinnant's algorithm).
pub fn utc_today() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    civil_from_days((secs / 86_400) as i64)
}

/// Convert days since 1970-01-01 to a `YYYY-MM-DD` string.
pub fn civil_from_days(z: i64) -> String {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = (z - era * 146_097) as u64; // [0, 146096]
    let yoe =
        (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe as i64 + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if m <= 2 { y + 1 } else { y };
    format!("{y:04}-{m:02}-{d:02}")
}

/// Append `pairs` to a perf-trajectory artifact: a flat JSON object
/// (the same shape [`parse_flat_json`] reads) whose keys are
/// `"<date>/<bench name>"`. Existing entries for `date` are replaced
/// — re-running `--update-baseline` on the same day updates that
/// day's point instead of duplicating it — and every other date's
/// entries are preserved, so the committed file accumulates one
/// dated snapshot per baseline refresh across PRs.
pub fn trajectory_with(
    existing: &str,
    date: &str,
    pairs: &[(String, f64)],
) -> anyhow::Result<String> {
    let mut all: Vec<(String, f64)> = if existing.trim().is_empty() {
        Vec::new()
    } else {
        parse_flat_json(existing).map_err(|e| {
            anyhow::anyhow!("trajectory file is not flat JSON: {e}")
        })?
    };
    let prefix = format!("{date}/");
    all.retain(|(k, _)| !k.starts_with(&prefix));
    for (name, v) in pairs {
        all.push((format!("{date}/{name}"), *v));
    }
    Ok(flat_json(&all))
}

/// Outcome of [`gate_speedups`].
pub struct GateOutcome {
    /// Ratios compared against the baseline.
    pub checked: usize,
    /// Human-readable per-entry verdict lines.
    pub report: Vec<String>,
    /// Failure descriptions; empty means the gate passes.
    pub failures: Vec<String>,
}

/// Whether a bench entry is gated against the baseline:
/// `speedup/*` ratios (engine vs reference) and `size/*` metrics
/// (archive compression ratios) — bigger is better, one floor rule —
/// plus `mem/*` (peak replay memory in bytes), `lat/*` (serve-path
/// latencies in ms) and `acc/*` (timing-model accuracy: normalized
/// relative error vs the paper's published kernel times, written by
/// `rocline reproduce accuracy` as `accuracy_gate.json`) metrics,
/// where **lower** is better and the gate applies a ceiling instead.
pub fn is_gated_metric(name: &str) -> bool {
    name.starts_with("speedup/")
        || name.starts_with("size/")
        || name.starts_with("mem/")
        || name.starts_with("lat/")
        || name.starts_with("acc/")
}

/// Whether a gated metric regresses *upward* (`mem/*`: bytes held at
/// replay; `lat/*`: serve-path latencies in ms; `acc/*`: prediction
/// rel err — growth is the failure).
fn lower_is_better(name: &str) -> bool {
    name.starts_with("mem/")
        || name.starts_with("lat/")
        || name.starts_with("acc/")
}

/// The bench regression gate: every gated entry in `baseline` (see
/// [`is_gated_metric`]) must appear in `current` at no less than
/// `baseline * (1 - tolerance)` — or, for `mem/*` entries, at no
/// more than `baseline * (1 + tolerance)`. Entries only in `current`
/// pass with a note (new benches enter the baseline on the next
/// `--update-baseline`).
pub fn gate_speedups(
    current: &[(String, f64)],
    baseline: &[(String, f64)],
    tolerance: f64,
) -> GateOutcome {
    let mut out = GateOutcome {
        checked: 0,
        report: Vec::new(),
        failures: Vec::new(),
    };
    for (name, base) in baseline
        .iter()
        .filter(|(n, _)| is_gated_metric(n))
    {
        match current.iter().find(|(n, _)| n == name) {
            None => out.failures.push(format!(
                "{name}: missing from current run \
                 (baseline {base:.2}x; bench renamed or lost?)"
            )),
            Some((_, cur)) if lower_is_better(name) => {
                out.checked += 1;
                let ceiling = base * (1.0 + tolerance);
                let failed = *cur > ceiling;
                let verdict = if failed { "FAIL" } else { "ok" };
                out.report.push(format!(
                    "{verdict:>4}  {name:<44} {cur:>14.0} \
                     (baseline {base:.0}, ceiling {ceiling:.0})"
                ));
                if failed {
                    out.failures.push(format!(
                        "{name}: {cur:.0} exceeded the \
                         {ceiling:.0} ceiling (baseline {base:.0} \
                         + {:.0}%)",
                        tolerance * 100.0
                    ));
                }
            }
            Some((_, cur)) => {
                out.checked += 1;
                let floor = base * (1.0 - tolerance);
                let failed = *cur < floor;
                let verdict = if failed { "FAIL" } else { "ok" };
                out.report.push(format!(
                    "{verdict:>4}  {name:<44} {cur:>7.2}x \
                     (baseline {base:.2}x, floor {floor:.2}x)"
                ));
                if failed {
                    out.failures.push(format!(
                        "{name}: {cur:.2}x fell below the \
                         {floor:.2}x floor (baseline {base:.2}x \
                         - {:.0}%)",
                        tolerance * 100.0
                    ));
                }
            }
        }
    }
    for (name, cur) in current {
        if is_gated_metric(name)
            && !baseline.iter().any(|(n, _)| n == name)
        {
            out.report.push(format!(
                " new  {name:<44} {cur:>7.2}x (not in baseline yet)"
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast() -> BenchConfig {
        BenchConfig {
            warmup_iters: 1,
            samples: 3,
            iters_per_sample: 2,
        }
    }

    #[test]
    fn records_results() {
        let mut r = BenchRunner::new("test").with_config(fast());
        r.bench("noop", || 1 + 1);
        r.bench_throughput("sum", 1000, || (0..1000u64).sum::<u64>());
        let results = r.finish();
        assert_eq!(results.len(), 2);
        assert!(results[0].time.mean >= 0.0);
        assert!(results[1].throughput.unwrap() > 0.0);
    }

    #[test]
    fn json_artifact_shape() {
        let results = vec![
            BenchResult {
                name: "g/a".into(),
                time: Summary::of(&[0.5, 0.5]),
                throughput: Some(1000.0),
            },
            BenchResult {
                name: "g/b".into(),
                time: Summary::of(&[0.25, 0.25]),
                throughput: None,
            },
        ];
        let json = results_to_json(&results);
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        assert!(json.contains("\"g/a\": 1000.000,"), "{json}");
        assert!(json.contains("\"g/b\": 4.000\n"), "{json}");
    }

    #[test]
    fn flat_json_round_trips_through_the_parser() {
        let results = vec![
            BenchResult {
                name: "g/a".into(),
                time: Summary::of(&[0.5, 0.5]),
                throughput: Some(1234.5),
            },
            BenchResult {
                name: "speedup/x".into(),
                time: Summary::of(&[0.25]),
                throughput: Some(2.75),
            },
        ];
        let parsed =
            parse_flat_json(&results_to_json(&results)).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].0, "g/a");
        assert!((parsed[0].1 - 1234.5).abs() < 1e-9);
        assert_eq!(parsed[1].0, "speedup/x");
        assert!((parsed[1].1 - 2.75).abs() < 1e-9);
        // and the baseline writer's output parses too
        let again = parse_flat_json(&flat_json(&parsed)).unwrap();
        assert_eq!(again.len(), 2);
    }

    #[test]
    fn parser_accepts_hand_edits_and_rejects_junk() {
        let parsed = parse_flat_json(
            "{ \"a\": 1.5e3 ,\n\t\"b\":2 }",
        )
        .unwrap();
        assert_eq!(parsed[0], ("a".to_string(), 1500.0));
        assert_eq!(parsed[1], ("b".to_string(), 2.0));
        assert!(parse_flat_json("").is_err());
        assert!(parse_flat_json("{\"a\" 1}").is_err());
        assert!(parse_flat_json("{\"a\": nope}").is_err());
        assert!(parse_flat_json("{\"a\": 1").is_err());
    }

    #[test]
    fn gate_passes_within_tolerance_and_fails_below_floor() {
        let baseline = vec![
            ("speedup/a".to_string(), 2.0),
            ("speedup/b".to_string(), 1.0),
            ("other/ignored".to_string(), 9.0),
        ];
        // a: 1.7 >= 2.0*0.8 = 1.6 -> ok; b: 0.7 < 0.8 -> fail
        let current = vec![
            ("speedup/a".to_string(), 1.7),
            ("speedup/b".to_string(), 0.7),
            ("speedup/new".to_string(), 3.0),
        ];
        let out = gate_speedups(&current, &baseline, 0.2);
        assert_eq!(out.checked, 2);
        assert_eq!(out.failures.len(), 1);
        assert!(out.failures[0].contains("speedup/b"), "{:?}", out.failures);
        assert!(out
            .report
            .iter()
            .any(|l| l.contains("new") && l.contains("speedup/new")));
    }

    #[test]
    fn gate_covers_size_metrics_with_the_same_floor_rule() {
        // archive compression ratios regress downward exactly like
        // speedups: 4.0x baseline with 20% tolerance floors at 3.2x
        let baseline = vec![
            ("size/archive_compress_ratio".to_string(), 4.0),
            ("archive/spill_write".to_string(), 1e9), // not gated
        ];
        let ok = vec![(
            "size/archive_compress_ratio".to_string(),
            3.5,
        )];
        let out = gate_speedups(&ok, &baseline, 0.2);
        assert_eq!(out.checked, 1);
        assert!(out.failures.is_empty(), "{:?}", out.failures);

        let bad = vec![(
            "size/archive_compress_ratio".to_string(),
            2.0,
        )];
        let out = gate_speedups(&bad, &baseline, 0.2);
        assert_eq!(out.failures.len(), 1);
        assert!(
            out.failures[0].contains("size/archive_compress_ratio"),
            "{:?}",
            out.failures
        );
        // a size metric new in current is a note, not a failure
        let new = vec![
            (
                "size/archive_compress_ratio".to_string(),
                4.0,
            ),
            ("size/other".to_string(), 2.0),
        ];
        let out = gate_speedups(&new, &baseline, 0.2);
        assert!(out.failures.is_empty());
        assert!(out
            .report
            .iter()
            .any(|l| l.contains("new") && l.contains("size/other")));
        assert!(is_gated_metric("speedup/x"));
        assert!(is_gated_metric("size/x"));
        assert!(is_gated_metric("lat/x"));
        assert!(!is_gated_metric("trace/x"));
    }

    #[test]
    fn gate_mem_metrics_use_a_ceiling_rule() {
        // peak RSS regresses *upward*: 1 MB baseline with 20%
        // tolerance ceilings at 1.2 MB
        let baseline =
            vec![("mem/replay_peak_rss".to_string(), 1_000_000.0)];
        let ok =
            vec![("mem/replay_peak_rss".to_string(), 1_100_000.0)];
        let out = gate_speedups(&ok, &baseline, 0.2);
        assert_eq!(out.checked, 1);
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        // shrinking far below baseline is never a failure
        let small = vec![("mem/replay_peak_rss".to_string(), 10.0)];
        let out = gate_speedups(&small, &baseline, 0.2);
        assert!(out.failures.is_empty(), "{:?}", out.failures);

        let bad =
            vec![("mem/replay_peak_rss".to_string(), 1_300_000.0)];
        let out = gate_speedups(&bad, &baseline, 0.2);
        assert_eq!(out.failures.len(), 1);
        assert!(
            out.failures[0].contains("exceeded the"),
            "{:?}",
            out.failures
        );
        // missing from current is still a failure, and a mem metric
        // new in current is still just a note
        let out = gate_speedups(&[], &baseline, 0.2);
        assert_eq!(out.failures.len(), 1);
        let new = vec![
            ("mem/replay_peak_rss".to_string(), 1_000_000.0),
            ("mem/other".to_string(), 5.0),
        ];
        let out = gate_speedups(&new, &baseline, 0.2);
        assert!(out.failures.is_empty());
        assert!(out
            .report
            .iter()
            .any(|l| l.contains("new") && l.contains("mem/other")));
        assert!(is_gated_metric("mem/x"));
    }

    #[test]
    fn gate_acc_metrics_use_a_ceiling_rule() {
        // prediction rel err regresses upward: 0.5 baseline with 20%
        // tolerance ceilings at 0.6
        let baseline = vec![(
            "acc/predicted_time_rel_err_v100".to_string(),
            0.5,
        )];
        let ok = vec![(
            "acc/predicted_time_rel_err_v100".to_string(),
            0.55,
        )];
        let out = gate_speedups(&ok, &baseline, 0.2);
        assert_eq!(out.checked, 1);
        assert!(out.failures.is_empty(), "{:?}", out.failures);
        // a *more* accurate model (smaller err) always passes
        let better = vec![(
            "acc/predicted_time_rel_err_v100".to_string(),
            0.01,
        )];
        let out = gate_speedups(&better, &baseline, 0.2);
        assert!(out.failures.is_empty(), "{:?}", out.failures);

        let bad = vec![(
            "acc/predicted_time_rel_err_v100".to_string(),
            0.7,
        )];
        let out = gate_speedups(&bad, &baseline, 0.2);
        assert_eq!(out.failures.len(), 1);
        assert!(
            out.failures[0].contains("exceeded the"),
            "{:?}",
            out.failures
        );
        assert!(is_gated_metric("acc/x"));
        assert!(!is_gated_metric("accuracy/x"));
    }

    #[test]
    fn gate_flags_missing_benches() {
        let baseline = vec![("speedup/gone".to_string(), 1.5)];
        let out = gate_speedups(&[], &baseline, 0.2);
        assert_eq!(out.checked, 0);
        assert_eq!(out.failures.len(), 1);
        assert!(out.failures[0].contains("missing"));
    }

    #[test]
    fn civil_dates_from_epoch_days() {
        assert_eq!(civil_from_days(0), "1970-01-01");
        assert_eq!(civil_from_days(364), "1970-12-31");
        assert_eq!(civil_from_days(365), "1971-01-01");
        // leap handling: 2000-01-01 is day 10957; +31+29 lands on
        // 2000-03-01
        assert_eq!(civil_from_days(10_957), "2000-01-01");
        assert_eq!(civil_from_days(10_957 + 59), "2000-02-29");
        assert_eq!(civil_from_days(10_957 + 60), "2000-03-01");
        let today = utc_today();
        assert_eq!(today.len(), 10);
        assert_eq!(&today[4..5], "-");
    }

    #[test]
    fn trajectory_appends_and_replaces_same_day() {
        let day1 = trajectory_with(
            "",
            "2026-08-01",
            &[("speedup/a".to_string(), 1.5)],
        )
        .unwrap();
        assert!(day1.contains("\"2026-08-01/speedup/a\": 1.500"));

        // same day again: replaced, not duplicated
        let day1b = trajectory_with(
            &day1,
            "2026-08-01",
            &[("speedup/a".to_string(), 1.7)],
        )
        .unwrap();
        assert!(day1b.contains("1.700"), "{day1b}");
        assert!(!day1b.contains("1.500"), "{day1b}");

        // a later date accumulates alongside the first
        let day2 = trajectory_with(
            &day1b,
            "2026-09-01",
            &[("speedup/a".to_string(), 2.0)],
        )
        .unwrap();
        assert!(day2.contains("2026-08-01/speedup/a"), "{day2}");
        assert!(day2.contains("2026-09-01/speedup/a"), "{day2}");
        // and the result still round-trips through the parser
        assert_eq!(parse_flat_json(&day2).unwrap().len(), 2);

        // an empty-object seed file works too
        let seeded = trajectory_with(
            "{\n}\n",
            "2026-08-01",
            &[("speedup/x".to_string(), 1.0)],
        )
        .unwrap();
        assert!(seeded.contains("2026-08-01/speedup/x"));
        // corrupt files are a clean error, not a silent overwrite
        assert!(trajectory_with("not json", "d", &[]).is_err());
    }

    #[test]
    fn report_line_units() {
        let res = BenchResult {
            name: "g/x".into(),
            time: Summary::of(&[2e-9, 2e-9, 2e-9]),
            throughput: Some(5e8),
        };
        let line = res.report_line();
        assert!(line.contains("ns"), "{line}");
        assert!(line.contains("Melem/s"), "{line}");
    }
}
