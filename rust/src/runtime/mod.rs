//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. The interchange format is HLO **text**
//! (see `python/compile/aot.py` for why serialized protos are rejected
//! by xla_extension 0.5.1).
//!
//! Python never runs here: once `make artifacts` has produced
//! `artifacts/*.hlo.txt` + `manifest.txt`, the binary is self-contained.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArgSpec, Artifacts, EntryMeta};
pub use client::Runtime;
