//! The PJRT execution wrapper: compile cache + typed f32 execution.

use std::collections::HashMap;
use std::path::Path;

use super::artifacts::{Artifacts, EntryMeta};

/// A PJRT CPU client plus a compile cache over the AOT artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: Artifacts,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifact_dir: &Path) -> anyhow::Result<Runtime> {
        let artifacts = Artifacts::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime {
            client,
            artifacts,
            cache: HashMap::new(),
        })
    }

    pub fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) one entry point.
    fn executable(
        &mut self,
        name: &str,
    ) -> anyhow::Result<(&xla::PjRtLoadedExecutable, EntryMeta)> {
        let meta = self.artifacts.entry(name)?.clone();
        if !self.cache.contains_key(name) {
            let proto = xla::HloModuleProto::from_text_file(
                meta.file
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| {
                anyhow::anyhow!("parse {}: {e:?}", meta.file.display())
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok((self.cache.get(name).unwrap(), meta))
    }

    /// Execute an entry with f32 buffers; returns the tuple elements as
    /// f32 vectors (all our entries produce f32 outputs; `outs` comes
    /// from the manifest).
    pub fn call_f32(
        &mut self,
        name: &str,
        args: &[&[f32]],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let (exe, meta) = self.executable(name)?;
        anyhow::ensure!(
            args.len() == meta.args.len(),
            "{name}: got {} args, manifest says {}",
            args.len(),
            meta.args.len()
        );
        let mut literals = Vec::with_capacity(args.len());
        for (i, (buf, spec)) in
            args.iter().zip(meta.args.iter()).enumerate()
        {
            anyhow::ensure!(
                buf.len() == spec.elements(),
                "{name} arg {i}: got {} elements, manifest says {} \
                 ({:?})",
                buf.len(),
                spec.elements(),
                spec
            );
            let lit = xla::Literal::vec1(buf);
            let lit = if spec.dims.len() > 1 {
                lit.reshape(&spec.dims_i64())
                    .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?
            } else {
                lit
            };
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == meta.outs,
            "{name}: got {} outputs, manifest says {}",
            parts.len(),
            meta.outs
        );
        parts
            .into_iter()
            .map(|p| {
                p.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
            })
            .collect()
    }

    /// Time one call (after an untimed warmup call), returning
    /// (outputs, seconds). Used by the PJRT BabelStream backend.
    pub fn time_call_f32(
        &mut self,
        name: &str,
        args: &[&[f32]],
        iters: u32,
    ) -> anyhow::Result<(Vec<Vec<f32>>, f64)> {
        let _ = self.call_f32(name, args)?; // warmup + compile
        let t0 = std::time::Instant::now();
        let mut out = Vec::new();
        for _ in 0..iters {
            out = self.call_f32(name, args)?;
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        Ok((out, dt))
    }
}

#[cfg(test)]
mod tests {
    //! Full PJRT round-trip tests live in `rust/tests/pjrt_roundtrip.rs`
    //! (they need `make artifacts` to have run). Here: path-independent
    //! error behaviour only.
    use super::*;

    #[test]
    fn missing_artifact_dir_is_a_clean_error() {
        let err = Runtime::new(Path::new("/nonexistent/artifacts"))
            .err()
            .expect("must fail");
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
