//! AOT artifact discovery: parse `artifacts/manifest.txt`.
//!
//! The manifest is the contract between `python/compile/aot.py` and the
//! Rust runtime: entry names, argument shapes/dtypes, output arities, and
//! the physics constants baked into each case's HLO.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::pic::CaseConfig;

/// One argument's shape/dtype, e.g. `float32[8192,3]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl ArgSpec {
    pub fn parse(s: &str) -> Option<ArgSpec> {
        let (dtype, rest) = s.split_once('[')?;
        let dims_str = rest.strip_suffix(']')?;
        let dims = if dims_str.is_empty() {
            Vec::new()
        } else {
            dims_str
                .split(',')
                .map(|d| d.parse().ok())
                .collect::<Option<Vec<usize>>>()?
        };
        Some(ArgSpec {
            dtype: dtype.to_string(),
            dims,
        })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Dims as i64 for `Literal::reshape`.
    pub fn dims_i64(&self) -> Vec<i64> {
        self.dims.iter().map(|&d| d as i64).collect()
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub name: String,
    pub file: PathBuf,
    pub outs: usize,
    pub args: Vec<ArgSpec>,
    /// Science case this entry belongs to, if any.
    pub case: Option<String>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub entries: HashMap<String, EntryMeta>,
    pub cases: HashMap<String, CaseConfig>,
}

impl Artifacts {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> anyhow::Result<Artifacts> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Artifacts> {
        let mut out = Artifacts {
            dir: dir.to_path_buf(),
            ..Default::default()
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(cfg) = CaseConfig::from_manifest_line(line) {
                out.cases.insert(cfg.name.clone(), cfg);
            } else if let Some(rest) = line.strip_prefix("entry ") {
                let mut kv = HashMap::new();
                for part in rest.split_whitespace() {
                    if let Some((k, v)) = part.split_once('=') {
                        kv.insert(k, v);
                    }
                }
                let name = kv
                    .get("name")
                    .ok_or_else(|| anyhow::anyhow!("entry without name"))?
                    .to_string();
                let file = dir.join(
                    kv.get("file")
                        .ok_or_else(|| {
                            anyhow::anyhow!("entry {name} without file")
                        })?,
                );
                let outs: usize = kv
                    .get("outs")
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| {
                        anyhow::anyhow!("entry {name}: bad outs")
                    })?;
                let args = kv
                    .get("args")
                    .map(|a| {
                        a.split(';')
                            .map(ArgSpec::parse)
                            .collect::<Option<Vec<_>>>()
                    })
                    .unwrap_or(Some(Vec::new()))
                    .ok_or_else(|| {
                        anyhow::anyhow!("entry {name}: bad args")
                    })?;
                out.entries.insert(
                    name.clone(),
                    EntryMeta {
                        name,
                        file,
                        outs,
                        args,
                        case: kv.get("case").map(|s| s.to_string()),
                    },
                );
            }
        }
        Ok(out)
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&EntryMeta> {
        self.entries.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "no AOT entry '{name}' in {} (have: {})",
                self.dir.display(),
                self.names().join(", ")
            )
        })
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> =
            self.entries.keys().cloned().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
case name=lwfa nx=16 ny=16 nz=16 ppc=2 dt=0.5 qm=-1.0 qw=-0.05 steps=64
entry name=pic_step_lwfa file=pic_step_lwfa.hlo.txt outs=4 \
args=float32[3,16,16,16];float32[3,16,16,16];float32[8192,3];float32[8192,3] case=lwfa
stream n=1048576 scalar=0.4
entry name=stream_copy file=stream_copy.hlo.txt outs=1 args=float32[1048576]
";

    #[test]
    fn parses_entries_and_cases() {
        let a =
            Artifacts::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(a.entries.len(), 2);
        assert_eq!(a.cases.len(), 1);
        let e = a.entry("pic_step_lwfa").unwrap();
        assert_eq!(e.outs, 4);
        assert_eq!(e.args.len(), 4);
        assert_eq!(e.args[0].dims, vec![3, 16, 16, 16]);
        assert_eq!(e.case.as_deref(), Some("lwfa"));
        assert_eq!(a.cases["lwfa"].particles(), 8192);
    }

    #[test]
    fn argspec_parse() {
        let s = ArgSpec::parse("float32[8192,3]").unwrap();
        assert_eq!(s.dtype, "float32");
        assert_eq!(s.elements(), 24576);
        assert_eq!(s.dims_i64(), vec![8192, 3]);
        assert!(ArgSpec::parse("garbage").is_none());
        assert!(ArgSpec::parse("f32[1,x]").is_none());
    }

    #[test]
    fn scalar_argspec() {
        let s = ArgSpec::parse("float32[]").unwrap();
        assert_eq!(s.dims.len(), 0);
        assert_eq!(s.elements(), 1);
    }

    #[test]
    fn missing_entry_error_lists_names() {
        let a =
            Artifacts::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let err = a.entry("nope").unwrap_err().to_string();
        assert!(err.contains("pic_step_lwfa"), "{err}");
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // integration hook: when `make artifacts` has run, validate the
        // real manifest agrees with the built-in case configs
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let a = Artifacts::load(&dir).unwrap();
        assert!(a.entries.len() >= 13, "{:?}", a.names());
        assert_eq!(a.cases["lwfa"], CaseConfig::lwfa());
        assert_eq!(a.cases["tweac"], CaseConfig::tweac());
    }
}
