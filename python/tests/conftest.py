"""Shared fixtures/strategies for the rocline python test suite."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


def random_fields(rng, dims, scale=1.0):
    """Random E,B field pair [3, nx, ny, nz] f32."""
    nx, ny, nz = dims
    e = (scale * rng.normal(size=(3, nx, ny, nz))).astype(np.float32)
    b = (scale * rng.normal(size=(3, nx, ny, nz))).astype(np.float32)
    return e, b


def random_particles(rng, n, dims, pmax=2.0):
    """Random particle state: pos in [0, dims), mom ~ N(0, pmax)."""
    nx, ny, nz = dims
    pos = (rng.random((n, 3)) * np.array([nx, ny, nz])).astype(np.float32)
    mom = (pmax * rng.normal(size=(n, 3))).astype(np.float32)
    return pos, mom
