"""AOT build smoke tests: HLO text artifacts + manifest format."""

import os
import re

import pytest

from compile import aot
from compile.cases import CASES


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out))
    return out


def test_all_entries_emitted(built):
    names = {f"{k}_{c}" for c in CASES
             for k in ("pic_step", "move_and_mark", "compute_current",
                       "field_update")}
    names |= {f"stream_{op}" for op in ("copy", "mul", "add", "triad", "dot")}
    for n in names:
        path = built / f"{n}.hlo.txt"
        assert path.exists(), f"missing artifact {n}"
        text = path.read_text()
        assert "ENTRY" in text, f"{n} does not look like HLO text"
        assert "HloModule" in text


def test_hlo_text_has_no_serialized_proto_markers(built):
    # Interchange MUST be text: parseable module header on line 1.
    for f in built.glob("*.hlo.txt"):
        first = f.read_text().splitlines()[0]
        assert first.startswith("HloModule"), f.name


def test_manifest_lists_every_entry(built):
    text = (built / "manifest.txt").read_text()
    entries = re.findall(r"^entry name=(\S+)", text, re.M)
    assert len(entries) == len(set(entries)) == 13


def test_manifest_case_lines_carry_constants(built):
    text = (built / "manifest.txt").read_text()
    for case in CASES.values():
        m = re.search(rf"^case name={case.name} (.+)$", text, re.M)
        assert m, f"no case line for {case.name}"
        kv = dict(p.split("=") for p in m.group(1).split())
        assert int(kv["nx"]) == case.nx
        assert float(kv["dt"]) == case.dt
        assert float(kv["qw"]) == case.qw


def test_manifest_arg_specs_parse(built):
    text = (built / "manifest.txt").read_text()
    for line in text.splitlines():
        if not line.startswith("entry "):
            continue
        m = re.search(r"args=(\S+)", line)
        assert m
        for spec in m.group(1).split(";"):
            assert re.fullmatch(r"(float32|int32)\[[0-9,]+\]", spec), spec


def test_pic_step_artifact_mentions_scatter(built):
    # the deposition lowers to an HLO scatter — guard against silently
    # losing the deposit when editing model.py
    text = (built / "pic_step_lwfa.hlo.txt").read_text()
    assert "scatter" in text
