"""Pallas PIC kernels vs pure-jnp oracles — the CORE correctness signal.

MoveAndMark (gather + Boris push + advance) and the ComputeCurrent hot loop
must match ref.py over hypothesis-swept shapes, block sizes, and particle
states, and satisfy physical invariants (bounds, stencil partition of
unity, gamma >= 1).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import pic, ref
from tests.conftest import random_fields, random_particles

dims_st = st.tuples(st.sampled_from([4, 8, 16]),
                    st.sampled_from([4, 8, 12]),
                    st.sampled_from([4, 8, 10]))
block_st = st.sampled_from([64, 128, 256])
seed_st = st.integers(0, 2**31 - 1)


@settings(max_examples=15, deadline=None)
@given(dims=dims_st, block=block_st, mult=st.integers(1, 4), seed=seed_st)
def test_move_and_mark_matches_ref(dims, block, mult, seed):
    rng = np.random.default_rng(seed)
    n = block * mult
    e, b = random_fields(rng, dims)
    pos, mom = random_particles(rng, n, dims)
    p1, m1 = pic.move_and_mark(jnp.asarray(e), jnp.asarray(b),
                               jnp.asarray(pos), jnp.asarray(mom),
                               qm=-1.0, dt=0.5, block=block)
    p2, m2 = ref.move_and_mark(jnp.asarray(e), jnp.asarray(b),
                               jnp.asarray(pos), jnp.asarray(mom), -1.0, 0.5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(dims=dims_st, block=block_st, mult=st.integers(1, 4), seed=seed_st)
def test_current_contributions_match_ref(dims, block, mult, seed):
    rng = np.random.default_rng(seed)
    n = block * mult
    pos, mom = random_particles(rng, n, dims)
    c1, k1 = pic.current_contributions(jnp.asarray(pos), jnp.asarray(mom),
                                       dims, block=block)
    c2, k2 = ref.current_contributions(jnp.asarray(pos), jnp.asarray(mom),
                                       dims)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(dims=dims_st, seed=seed_st)
def test_positions_stay_in_bounds(dims, seed):
    rng = np.random.default_rng(seed)
    e, b = random_fields(rng, dims, scale=5.0)
    pos, mom = random_particles(rng, 256, dims, pmax=10.0)
    p1, _ = pic.move_and_mark(jnp.asarray(e), jnp.asarray(b),
                              jnp.asarray(pos), jnp.asarray(mom),
                              qm=-1.0, dt=0.5, block=256)
    p = np.asarray(p1)
    hi = np.array(dims, dtype=np.float32)
    assert (p >= 0).all() and (p < hi).all()


@settings(max_examples=10, deadline=None)
@given(dims=dims_st, seed=seed_st)
def test_cells_in_range_and_weights_partition(dims, seed):
    """Stencil invariants: cell ids valid; per-particle |contrib| rows sum
    to v (partition of unity of the CIC weights)."""
    rng = np.random.default_rng(seed)
    nx, ny, nz = dims
    pos, mom = random_particles(rng, 256, dims)
    cell, contrib = pic.current_contributions(jnp.asarray(pos),
                                              jnp.asarray(mom),
                                              dims, block=256)
    c = np.asarray(cell)
    assert (c >= 0).all() and (c < nx * ny * nz).all()
    # sum over the 8 stencil corners == v exactly (weights sum to 1)
    mom_np = np.asarray(mom, dtype=np.float64)
    gamma = np.sqrt(1.0 + (mom_np ** 2).sum(axis=1, keepdims=True))
    v = mom_np / gamma
    np.testing.assert_allclose(np.asarray(contrib).sum(axis=1), v,
                               rtol=1e-4, atol=1e-5)


def test_gamma_never_below_one(rng):
    """Boris push preserves gamma >= 1 (no superluminal particles)."""
    dims = (8, 8, 8)
    e, b = random_fields(rng, dims, scale=20.0)
    pos, mom = random_particles(rng, 512, dims, pmax=50.0)
    _, m1 = pic.move_and_mark(jnp.asarray(e), jnp.asarray(b),
                              jnp.asarray(pos), jnp.asarray(mom),
                              qm=-1.0, dt=0.5, block=512)
    m = np.asarray(m1, dtype=np.float64)
    gamma = np.sqrt(1.0 + (m ** 2).sum(axis=1))
    assert (gamma >= 1.0).all()
    assert np.isfinite(m).all()


def test_pure_magnetic_rotation_preserves_energy(rng):
    """With E=0 the Boris rotation must conserve |u| per particle."""
    dims = (8, 8, 8)
    e = np.zeros((3, *dims), dtype=np.float32)
    _, b = random_fields(rng, dims, scale=5.0)
    pos, mom = random_particles(rng, 512, dims, pmax=5.0)
    _, m1 = pic.move_and_mark(jnp.asarray(e), jnp.asarray(b),
                              jnp.asarray(pos), jnp.asarray(mom),
                              qm=-1.0, dt=0.5, block=512)
    u0 = np.linalg.norm(np.asarray(mom, dtype=np.float64), axis=1)
    u1 = np.linalg.norm(np.asarray(m1, dtype=np.float64), axis=1)
    np.testing.assert_allclose(u1, u0, rtol=2e-4, atol=1e-5)


def test_block_must_divide_particles():
    e = jnp.zeros((3, 4, 4, 4), jnp.float32)
    pos = jnp.zeros((100, 3), jnp.float32)
    with pytest.raises(ValueError):
        pic.move_and_mark(e, e, pos, pos, qm=-1.0, dt=0.5, block=64)
    with pytest.raises(ValueError):
        pic.current_contributions(pos, pos, (4, 4, 4), block=64)


def test_zero_momentum_particles_do_not_move_without_fields():
    dims = (4, 4, 4)
    e = jnp.zeros((3, *dims), jnp.float32)
    pos = jnp.asarray(np.full((64, 3), 1.25, dtype=np.float32))
    mom = jnp.zeros((64, 3), jnp.float32)
    p1, m1 = pic.move_and_mark(e, e, pos, mom, qm=-1.0, dt=0.5, block=64)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(pos))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(mom))
