"""Layer-2 model tests: full PIC step vs reference + physics invariants."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.cases import CASES, LWFA
from compile.kernels import ref
from tests.conftest import random_fields, random_particles

DIMS = (8, 8, 8)
N = 512


def _state(rng, dims=DIMS, n=N):
    e, b = random_fields(rng, dims, scale=0.1)
    pos, mom = random_particles(rng, n, dims, pmax=1.0)
    return (jnp.asarray(e), jnp.asarray(b),
            jnp.asarray(pos), jnp.asarray(mom))


def test_pic_step_matches_ref(rng):
    e, b, pos, mom = _state(rng)
    got = model.pic_step(e, b, pos, mom, qm=-1.0, qw=-0.05, dt=0.5)
    want = ref.pic_step(e, b, pos, mom, -1.0, -0.05, 0.5)
    for g, w, name in zip(got, want, ["e", "b", "pos", "mom"]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5, err_msg=name)


def test_compute_current_matches_ref(rng):
    _, _, pos, mom = _state(rng)
    got = model.compute_current(pos, mom, DIMS, qw=-0.05)
    want = ref.deposit_current(pos, mom, DIMS, -0.05)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-6)


def test_total_current_equals_total_velocity(rng):
    """Deposition conservation: sum_cells J = qw * sum_particles v."""
    _, _, pos, mom = _state(rng)
    j = model.compute_current(pos, mom, DIMS, qw=-0.05)
    m = np.asarray(mom, dtype=np.float64)
    gamma = np.sqrt(1.0 + (m ** 2).sum(axis=1, keepdims=True))
    v = m / gamma
    want = -0.05 * v.sum(axis=0)
    got = np.asarray(j, dtype=np.float64).reshape(3, -1).sum(axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_field_update_no_sources_preserves_uniform_field():
    """curl of a uniform field is 0: E,B constant in space stay constant."""
    e = jnp.full((3, *DIMS), 0.25, jnp.float32)
    b = jnp.full((3, *DIMS), -0.5, jnp.float32)
    j = jnp.zeros((3, *DIMS), jnp.float32)
    e2, b2 = model.field_update(e, b, j, dt=0.5)
    np.testing.assert_array_equal(np.asarray(e2), np.asarray(e))
    np.testing.assert_array_equal(np.asarray(b2), np.asarray(b))


def test_field_update_divergence_b_preserved(rng):
    """Central-difference curl keeps div B = 0 (discrete identity)."""
    def div(f):
        out = np.zeros(f.shape[1:])
        for ax in range(3):
            out += 0.5 * (np.roll(f[ax], -1, axis=ax)
                          - np.roll(f[ax], 1, axis=ax))
        return out
    e, b = random_fields(rng, DIMS, scale=1.0)
    j = np.zeros_like(e)
    d0 = div(np.asarray(b, dtype=np.float64))
    e2, b2 = model.field_update(jnp.asarray(e), jnp.asarray(b),
                                jnp.asarray(j), dt=0.5)
    d1 = div(np.asarray(b2, dtype=np.float64))
    np.testing.assert_allclose(d1, d0, atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_multi_step_stays_finite(seed):
    rng = np.random.default_rng(seed)
    e, b, pos, mom = _state(rng)
    for _ in range(5):
        e, b, pos, mom = model.pic_step(e, b, pos, mom,
                                        qm=-1.0, qw=-0.05, dt=0.5)
    for arr in (e, b, pos, mom):
        assert np.isfinite(np.asarray(arr)).all()


def test_case_specs_consistent():
    for case in CASES.values():
        assert case.particles == case.cells * case.ppc
        assert case.particles % 256 == 0, "block size must divide particles"
        assert case.dt < 1.0 / np.sqrt(3.0), "CFL violated"


def test_case_shapes_roundtrip():
    assert LWFA.field_shape == (3, 40, 40, 40)
    assert LWFA.particle_shape == (256000, 3)
