"""Pallas stream kernels vs pure-jnp oracles (BabelStream ops).

Hypothesis sweeps array lengths and block sizes; every op must match the
reference bit-tight (copy/mul/add/triad are elementwise) or to f32 reduce
tolerance (dot).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stream


def _arr(rng, n):
    return jnp.asarray(rng.normal(size=(n,)).astype(np.float32))


# block sizes dividing n are required; generate (block, multiplier) pairs.
blocks = st.sampled_from([128, 256, 1024, 4096])
mults = st.integers(min_value=1, max_value=6)


@settings(max_examples=20, deadline=None)
@given(block=blocks, mult=mults, seed=st.integers(0, 2**31 - 1))
def test_copy_matches_ref(block, mult, seed):
    rng = np.random.default_rng(seed)
    a = _arr(rng, block * mult)
    got = stream.copy(a, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.stream_copy(a)))


@settings(max_examples=20, deadline=None)
@given(block=blocks, mult=mults, seed=st.integers(0, 2**31 - 1),
       scalar=st.floats(-3, 3, allow_nan=False, width=32))
def test_mul_matches_ref(block, mult, seed, scalar):
    rng = np.random.default_rng(seed)
    c = _arr(rng, block * mult)
    got = stream.mul(c, np.float32(scalar), block=block)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.stream_mul(c, np.float32(scalar))),
                               rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(block=blocks, mult=mults, seed=st.integers(0, 2**31 - 1))
def test_add_matches_ref(block, mult, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, block * mult), _arr(rng, block * mult)
    got = stream.add(a, b, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.stream_add(a, b)))


@settings(max_examples=20, deadline=None)
@given(block=blocks, mult=mults, seed=st.integers(0, 2**31 - 1),
       scalar=st.floats(-3, 3, allow_nan=False, width=32))
def test_triad_matches_ref(block, mult, seed, scalar):
    rng = np.random.default_rng(seed)
    b, c = _arr(rng, block * mult), _arr(rng, block * mult)
    got = stream.triad(b, c, np.float32(scalar), block=block)
    # pallas path may emit an FMA for b + scalar*c; allow 2-ulp slack
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.stream_triad(b, c, np.float32(scalar))),
        rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(block=blocks, mult=mults, seed=st.integers(0, 2**31 - 1))
def test_dot_matches_ref(block, mult, seed):
    rng = np.random.default_rng(seed)
    n = block * mult
    a, b = _arr(rng, n), _arr(rng, n)
    got = float(stream.dot(a, b, block=block))
    want = float(ref.stream_dot(a, b))
    assert got == pytest.approx(want, rel=1e-4, abs=1e-3)


def test_block_must_divide_length():
    a = jnp.zeros((100,), jnp.float32)
    with pytest.raises(ValueError):
        stream.copy(a, block=64)


def test_dot_partials_shape():
    # dot with g blocks reduces g partials; check against numpy double acc
    rng = np.random.default_rng(7)
    a, b = _arr(rng, 8 * 1024), _arr(rng, 8 * 1024)
    got = float(stream.dot(a, b, block=1024))
    want = float(np.dot(np.asarray(a, dtype=np.float64),
                        np.asarray(b, dtype=np.float64)))
    assert got == pytest.approx(want, rel=1e-3)
