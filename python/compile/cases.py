"""Shared simulation-case constants for the AOT build.

These constants are baked into the lowered HLO at compile time and recorded
in ``artifacts/manifest.txt`` so the Rust coordinator (``rust/src/pic``) uses
*identical* numerics. Units are normalized PIC units: c = 1, eps0 = 1, cell
sizes in units of dx.

The two cases mirror the paper's PIConGPU science cases at laptop scale:

* ``lwfa``  — Laser Wakefield Acceleration: single pulse, small cube.
* ``tweac`` — Traveling Wave Electron Acceleration: two crossed pulses,
  larger cube, longer run.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class CaseSpec:
    """Geometry + physics constants for one science case."""

    name: str
    nx: int
    ny: int
    nz: int
    ppc: int          # particles per cell
    dt: float         # timestep (CFL: dt < 1/sqrt(3) for dx=1, c=1)
    qm: float         # charge/mass ratio of the species (electrons: -1)
    qw: float         # deposition factor: q * macroweight / cell volume
    steps: int        # default number of steps for the mini run

    @property
    def cells(self) -> int:
        return self.nx * self.ny * self.nz

    @property
    def particles(self) -> int:
        return self.cells * self.ppc

    @property
    def field_shape(self):
        return (3, self.nx, self.ny, self.nz)

    @property
    def particle_shape(self):
        return (self.particles, 3)


# Sizes are chosen so the per-step working set (pos+mom+E+B+J) exceeds
# every modeled GPU's L2 (4-8 MiB): the paper's FETCH_SIZE/WRITE_SIZE
# behaviour only appears when the particle data does not stay resident.
LWFA = CaseSpec(
    name="lwfa", nx=40, ny=40, nz=40, ppc=4,
    dt=0.5, qm=-1.0, qw=-0.05, steps=64,
)

TWEAC = CaseSpec(
    name="tweac", nx=48, ny=48, nz=48, ppc=4,
    dt=0.5, qm=-1.0, qw=-0.05, steps=96,
)

CASES = {c.name: c for c in (LWFA, TWEAC)}

# BabelStream-on-PJRT array length (number of f32 elements per array).
STREAM_N = 1 << 20
# Scalar used by the mul/triad stream kernels (BabelStream's startScalar).
STREAM_SCALAR = 0.4

# Default particle block size for the Pallas kernels. Must divide the
# particle count of every case (lwfa: 8192, tweac: 27648 — both /256).
PARTICLE_BLOCK = 256
