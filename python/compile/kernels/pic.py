"""Layer-1 Pallas kernels for the PIC hot loops.

The paper's two kernels of interest (PIConGPU §5):

* ``MoveAndMark``    — field gather + relativistic Boris push + position
                       advance. Here: :func:`move_and_mark`.
* ``ComputeCurrent`` — per-particle CIC current deposition. The per-particle
                       arithmetic (velocity, stencil weights, cell ids) is
                       the Pallas kernel :func:`current_contributions`; the
                       scatter-add lives in Layer 2 (``model.py``) as a
                       segmented accumulation, the standard TPU-friendly
                       re-expression of GPU atomics (DESIGN.md
                       §Hardware-Adaptation).

Tiling: particles are processed in blocks of ``PARTICLE_BLOCK`` (the analog
of PIConGPU's supercell frames); the field arrays are small enough for the
whole [3, nx, ny, nz] block to sit in VMEM, so each particle tile sees the
full field (BlockSpec index-map pinned to block 0).

``interpret=True`` everywhere — see DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # package-relative when imported as compile.kernels.pic
    from ..cases import PARTICLE_BLOCK
except ImportError:  # pragma: no cover - direct script import
    from compile.cases import PARTICLE_BLOCK


def _gather_one(field, pos, nx, ny, nz):
    """Trilinear gather inside the kernel. field: [3,nx,ny,nz], pos: [b,3]."""
    g = pos - 0.5
    i0f = jnp.floor(g)
    f = g - i0f
    i0 = i0f.astype(jnp.int32)
    out = jnp.zeros((pos.shape[0], 3), dtype=field.dtype)
    for cx in (0, 1):
        for cy in (0, 1):
            for cz in (0, 1):
                ix = jnp.mod(i0[:, 0] + cx, nx)
                iy = jnp.mod(i0[:, 1] + cy, ny)
                iz = jnp.mod(i0[:, 2] + cz, nz)
                wx = f[:, 0] if cx else 1.0 - f[:, 0]
                wy = f[:, 1] if cy else 1.0 - f[:, 1]
                wz = f[:, 2] if cz else 1.0 - f[:, 2]
                w = wx * wy * wz
                out = out + (field[:, ix, iy, iz] * w).T
    return out


def _push_kernel(qm, dt, dims, e_ref, b_ref, pos_ref, mom_ref,
                 npos_ref, nmom_ref):
    """MoveAndMark over one particle tile."""
    nx, ny, nz = dims
    e = e_ref[...]
    b = b_ref[...]
    pos = pos_ref[...]
    mom = mom_ref[...]

    ep = _gather_one(e, pos, nx, ny, nz)
    bp = _gather_one(b, pos, nx, ny, nz)

    # Relativistic Boris rotation (Birdsall & Langdon form).
    h = 0.5 * qm * dt
    um = mom + h * ep
    gamma = jnp.sqrt(1.0 + jnp.sum(um * um, axis=-1, keepdims=True))
    t = (h / gamma) * bp
    t2 = jnp.sum(t * t, axis=-1, keepdims=True)
    s = 2.0 * t / (1.0 + t2)
    up = um + jnp.cross(um, t)
    uplus = um + jnp.cross(up, s)
    new_mom = uplus + h * ep

    # Position advance + periodic wrap ("Mark" is the frame bookkeeping in
    # PIConGPU; under periodic boundaries the wrap is the whole of it).
    ng = jnp.sqrt(1.0 + jnp.sum(new_mom * new_mom, axis=-1, keepdims=True))
    v = new_mom / ng
    adv = pos + dt * v
    # Per-axis wrap with python-scalar moduli (a captured [3] array constant
    # is rejected by pallas kernel tracing).
    new_pos = jnp.stack(
        [jnp.mod(adv[:, 0], float(nx)),
         jnp.mod(adv[:, 1], float(ny)),
         jnp.mod(adv[:, 2], float(nz))], axis=1)

    npos_ref[...] = new_pos
    nmom_ref[...] = new_mom


def _contrib_kernel(dims, pos_ref, mom_ref, cell_ref, contrib_ref):
    """ComputeCurrent hot loop over one particle tile."""
    nx, ny, nz = dims
    pos = pos_ref[...]
    mom = mom_ref[...]
    gamma = jnp.sqrt(1.0 + jnp.sum(mom * mom, axis=-1, keepdims=True))
    v = mom / gamma

    g = pos - 0.5
    i0f = jnp.floor(g)
    f = g - i0f
    i0 = i0f.astype(jnp.int32)

    cells = []
    contribs = []
    for cx in (0, 1):
        for cy in (0, 1):
            for cz in (0, 1):
                ix = jnp.mod(i0[:, 0] + cx, nx)
                iy = jnp.mod(i0[:, 1] + cy, ny)
                iz = jnp.mod(i0[:, 2] + cz, nz)
                wx = f[:, 0] if cx else 1.0 - f[:, 0]
                wy = f[:, 1] if cy else 1.0 - f[:, 1]
                wz = f[:, 2] if cz else 1.0 - f[:, 2]
                w = (wx * wy * wz)[:, None]
                cells.append((ix * ny + iy) * nz + iz)
                contribs.append(w * v)
    cell_ref[...] = jnp.stack(cells, axis=1).astype(jnp.int32)
    contrib_ref[...] = jnp.stack(contribs, axis=1)


def _particle_specs(block):
    return pl.BlockSpec((block, 3), lambda i: (i, 0))


def _field_spec(shape):
    return pl.BlockSpec(shape, lambda i: (0, 0, 0, 0))


def move_and_mark(e, b, pos, mom, *, qm, dt, block=PARTICLE_BLOCK):
    """Pallas MoveAndMark: returns (new_pos [n,3], new_mom [n,3])."""
    n = pos.shape[0]
    if n % block != 0:
        raise ValueError(f"particle count {n} must be a multiple of {block}")
    dims = e.shape[1:]
    kern = functools.partial(_push_kernel, qm, dt, dims)
    return pl.pallas_call(
        kern,
        grid=(n // block,),
        in_specs=[_field_spec(e.shape), _field_spec(b.shape),
                  _particle_specs(block), _particle_specs(block)],
        out_specs=(_particle_specs(block), _particle_specs(block)),
        out_shape=(jax.ShapeDtypeStruct((n, 3), pos.dtype),
                   jax.ShapeDtypeStruct((n, 3), mom.dtype)),
        interpret=True,
    )(e, b, pos, mom)


def current_contributions(pos, mom, dims, *, block=PARTICLE_BLOCK):
    """Pallas ComputeCurrent hot loop.

    Returns (cell [n,8] int32, contrib [n,8,3] f32) — the caller scales by
    qw and scatter-adds into J (see ``model.compute_current``).
    """
    n = pos.shape[0]
    if n % block != 0:
        raise ValueError(f"particle count {n} must be a multiple of {block}")
    kern = functools.partial(_contrib_kernel, dims)
    return pl.pallas_call(
        kern,
        grid=(n // block,),
        in_specs=[_particle_specs(block), _particle_specs(block)],
        out_specs=(pl.BlockSpec((block, 8), lambda i: (i, 0)),
                   pl.BlockSpec((block, 8, 3), lambda i: (i, 0, 0))),
        out_shape=(jax.ShapeDtypeStruct((n, 8), jnp.int32),
                   jax.ShapeDtypeStruct((n, 8, 3), jnp.float32)),
        interpret=True,
    )(pos, mom)
