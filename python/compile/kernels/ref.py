"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests`` asserts the Pallas
kernels (interpret mode) match these to float32 tolerance, and the Rust
native implementation (``rust/src/pic``) is cross-checked against the AOT
artifacts lowered from the Pallas path.

All functions are shape-polymorphic pure jnp and run under jit.
"""

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# BabelStream ops (Deakin et al. 2016), jnp versions.
# ---------------------------------------------------------------------------

def stream_copy(a):
    """c = a"""
    return a * 1.0


def stream_mul(c, scalar):
    """b = scalar * c"""
    return scalar * c


def stream_add(a, b):
    """c = a + b"""
    return a + b


def stream_triad(b, c, scalar):
    """a = b + scalar * c"""
    return b + scalar * c


def stream_dot(a, b):
    """sum = a . b"""
    return jnp.sum(a * b, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# PIC primitives: CIC gather, Boris push, CIC current contributions.
# ---------------------------------------------------------------------------

def cic_weights(pos):
    """Cloud-in-cell interpolation stencil for cell-centered fields.

    Fields live at cell centers ``(i + 0.5)`` (dx = 1). Returns
    ``(i0, frac)`` where ``i0`` is the lower cell index per axis (unwrapped,
    int32) and ``frac`` in [0,1) the offset within the stencil.

    pos: [n, 3] float32.
    """
    g = pos - 0.5
    i0 = jnp.floor(g)
    frac = g - i0
    return i0.astype(jnp.int32), frac


def cic_gather(field, pos):
    """Trilinear gather of a [3, nx, ny, nz] field at particle positions.

    Returns [n, 3] field values. Periodic wrap on all axes.
    """
    _, nx, ny, nz = field.shape
    i0, f = cic_weights(pos)
    out = jnp.zeros((pos.shape[0], 3), dtype=field.dtype)
    for cx in (0, 1):
        for cy in (0, 1):
            for cz in (0, 1):
                ix = jnp.mod(i0[:, 0] + cx, nx)
                iy = jnp.mod(i0[:, 1] + cy, ny)
                iz = jnp.mod(i0[:, 2] + cz, nz)
                wx = f[:, 0] if cx else 1.0 - f[:, 0]
                wy = f[:, 1] if cy else 1.0 - f[:, 1]
                wz = f[:, 2] if cz else 1.0 - f[:, 2]
                w = wx * wy * wz
                vals = field[:, ix, iy, iz]          # [3, n]
                out = out + (vals * w).T
    return out


def boris_push(ep, bp, mom, qm, dt):
    """Relativistic Boris rotation. mom is u = gamma*v; returns new u.

    ep, bp, mom: [n, 3]; qm, dt scalars.
    """
    h = 0.5 * qm * dt
    um = mom + h * ep
    gamma = jnp.sqrt(1.0 + jnp.sum(um * um, axis=-1, keepdims=True))
    t = (h / gamma) * bp
    t2 = jnp.sum(t * t, axis=-1, keepdims=True)
    s = 2.0 * t / (1.0 + t2)
    up = um + jnp.cross(um, t)
    uplus = um + jnp.cross(up, s)
    return uplus + h * ep


def advance_position(pos, mom, dt, dims):
    """x += dt * u / gamma, periodic wrap into [0, dims)."""
    gamma = jnp.sqrt(1.0 + jnp.sum(mom * mom, axis=-1, keepdims=True))
    v = mom / gamma
    new = pos + dt * v
    d = jnp.asarray(dims, dtype=pos.dtype)
    return jnp.mod(new, d)


def move_and_mark(e, b, pos, mom, qm, dt):
    """Reference MoveAndMark: gather + Boris push + position advance."""
    ep = cic_gather(e, pos)
    bp = cic_gather(b, pos)
    new_mom = boris_push(ep, bp, mom, qm, dt)
    dims = e.shape[1:]
    new_pos = advance_position(pos, new_mom, dt, dims)
    return new_pos, new_mom


def current_contributions(pos, mom, dims):
    """Per-particle CIC current stencil (the ComputeCurrent hot loop).

    Returns (cell [n, 8] int32 flattened cell ids, contrib [n, 8, 3] f32):
    contribution of each particle to each of its 8 neighbour cells, where
    contrib = w_corner * v and the caller scales by qw and scatter-adds.
    """
    nx, ny, nz = dims
    gamma = jnp.sqrt(1.0 + jnp.sum(mom * mom, axis=-1, keepdims=True))
    v = mom / gamma                                   # [n, 3]
    i0, f = cic_weights(pos)
    cells = []
    contribs = []
    for cx in (0, 1):
        for cy in (0, 1):
            for cz in (0, 1):
                ix = jnp.mod(i0[:, 0] + cx, nx)
                iy = jnp.mod(i0[:, 1] + cy, ny)
                iz = jnp.mod(i0[:, 2] + cz, nz)
                wx = f[:, 0] if cx else 1.0 - f[:, 0]
                wy = f[:, 1] if cy else 1.0 - f[:, 1]
                wz = f[:, 2] if cz else 1.0 - f[:, 2]
                w = (wx * wy * wz)[:, None]           # [n, 1]
                cells.append((ix * ny + iy) * nz + iz)
                contribs.append(w * v)
    cell = jnp.stack(cells, axis=1).astype(jnp.int32)   # [n, 8]
    contrib = jnp.stack(contribs, axis=1)               # [n, 8, 3]
    return cell, contrib


def deposit_current(pos, mom, dims, qw):
    """Full reference ComputeCurrent: scatter-add contributions to J."""
    nx, ny, nz = dims
    cell, contrib = current_contributions(pos, mom, dims)
    flat_cell = cell.reshape(-1)                        # [n*8]
    flat_contrib = contrib.reshape(-1, 3) * qw          # [n*8, 3]
    j = jnp.zeros((nx * ny * nz, 3), dtype=jnp.float32)
    j = j.at[flat_cell].add(flat_contrib)
    return j.T.reshape(3, nx, ny, nz)


# ---------------------------------------------------------------------------
# Field solver: central-difference curl on the periodic cell-centered grid.
# ---------------------------------------------------------------------------

def curl(field):
    """Central-difference curl of a [3, nx, ny, nz] field, periodic, dx=1."""
    def d(comp, axis):
        # comp: [nx, ny, nz]; axis: 0=x, 1=y, 2=z spatial axis
        return 0.5 * (jnp.roll(comp, -1, axis=axis)
                      - jnp.roll(comp, 1, axis=axis))
    fx, fy, fz = field[0], field[1], field[2]
    cx = d(fz, 1) - d(fy, 2)     # dFz/dy - dFy/dz
    cy = d(fx, 2) - d(fz, 0)     # dFx/dz - dFz/dx
    cz = d(fy, 0) - d(fx, 1)     # dFy/dx - dFx/dy
    return jnp.stack([cx, cy, cz], axis=0)


def field_update(e, b, j, dt):
    """E += dt (curl B - J); B -= dt curl E' (semi-implicit leapfrog)."""
    e_new = e + dt * (curl(b) - j)
    b_new = b - dt * curl(e_new)
    return e_new, b_new


def pic_step(e, b, pos, mom, qm, qw, dt):
    """One full reference PIC step (MoveAndMark + ComputeCurrent + fields)."""
    new_pos, new_mom = move_and_mark(e, b, pos, mom, qm, dt)
    j = deposit_current(new_pos, new_mom, e.shape[1:], qw)
    e_new, b_new = field_update(e, b, j, dt)
    return e_new, b_new, new_pos, new_mom
