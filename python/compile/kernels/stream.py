"""Layer-1 Pallas kernels for the BabelStream operations.

BabelStream (Deakin et al. 2016) is the bandwidth yardstick the paper uses
for the AMD roofline ceilings (§6.2). These kernels are the PJRT-executed
backend of ``rust/src/babelstream``: the Rust harness times them end-to-end
through the compiled HLO.

All kernels are 1-D block-tiled. ``interpret=True`` everywhere: real-TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute (see
DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block length for the 1-D stream kernels. 8 * 128 * 16 lanes — a multiple
# of the (8, 128) f32 vreg tile so the VPU layout is dense.
BLOCK = 16384


def _grid(n, block):
    if n % block != 0:
        raise ValueError(f"stream length {n} must be a multiple of {block}")
    return n // block


def _spec(block):
    return pl.BlockSpec((block,), lambda i: (i,))


def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _mul_kernel(scalar, c_ref, b_ref):
    b_ref[...] = scalar * c_ref[...]


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(scalar, b_ref, c_ref, a_ref):
    a_ref[...] = b_ref[...] + scalar * c_ref[...]


def _dot_kernel(a_ref, b_ref, o_ref):
    # Per-block partial dot product; the caller reduces over blocks.
    o_ref[...] = jnp.sum(a_ref[...] * b_ref[...], dtype=jnp.float32)[None]


def copy(a, *, block=BLOCK):
    """c = a"""
    n = a.shape[0]
    return pl.pallas_call(
        _copy_kernel,
        grid=(_grid(n, block),),
        in_specs=[_spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a)


def mul(c, scalar, *, block=BLOCK):
    """b = scalar * c"""
    n = c.shape[0]
    return pl.pallas_call(
        functools.partial(_mul_kernel, scalar),
        grid=(_grid(n, block),),
        in_specs=[_spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), c.dtype),
        interpret=True,
    )(c)


def add(a, b, *, block=BLOCK):
    """c = a + b"""
    n = a.shape[0]
    return pl.pallas_call(
        _add_kernel,
        grid=(_grid(n, block),),
        in_specs=[_spec(block), _spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=True,
    )(a, b)


def triad(b, c, scalar, *, block=BLOCK):
    """a = b + scalar * c"""
    n = b.shape[0]
    return pl.pallas_call(
        functools.partial(_triad_kernel, scalar),
        grid=(_grid(n, block),),
        in_specs=[_spec(block), _spec(block)],
        out_specs=_spec(block),
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=True,
    )(b, c)


def dot(a, b, *, block=BLOCK):
    """sum(a * b) — per-block partials in the kernel, final sum outside."""
    n = a.shape[0]
    g = _grid(n, block)
    partials = pl.pallas_call(
        _dot_kernel,
        grid=(g,),
        in_specs=[_spec(block), _spec(block)],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((g,), jnp.float32),
        interpret=True,
    )(a, b)
    return jnp.sum(partials, dtype=jnp.float32)
