"""Layer-2 JAX model: the PIC step and stream entry points.

Composes the Layer-1 Pallas kernels (``kernels/pic.py``, ``kernels/stream.py``)
into the jit-able functions that ``aot.py`` lowers to HLO text for the Rust
runtime. Python never runs on the request path: these functions exist only
to be lowered once at build time.

Entry points (all shapes fixed at lowering):

* ``move_and_mark``     — the paper's MoveAndMark kernel
* ``compute_current``   — the paper's ComputeCurrent kernel (Pallas hot loop
                          + scatter-add deposition)
* ``field_update``      — FDTD-style field solver step
* ``pic_step``          — one full PIC step (all of the above fused)
* ``stream_*``          — BabelStream ops for the PJRT stream backend
"""

import jax.numpy as jnp

try:  # package-relative when imported as compile.model
    from .kernels import pic as pic_kernels
    from .kernels import stream as stream_kernels
    from .kernels import ref
except ImportError:  # pragma: no cover - direct script import
    from compile.kernels import pic as pic_kernels
    from compile.kernels import stream as stream_kernels
    from compile.kernels import ref


def move_and_mark(e, b, pos, mom, *, qm, dt):
    """MoveAndMark: gather + Boris push + advance (Pallas)."""
    return pic_kernels.move_and_mark(e, b, pos, mom, qm=qm, dt=dt)


def compute_current(pos, mom, dims, *, qw):
    """ComputeCurrent: Pallas per-particle stencil + scatter-add deposit.

    The scatter-add is the L2 re-expression of PIConGPU's GPU atomics: all
    per-particle contributions are produced by the Pallas kernel, then
    accumulated with a single XLA scatter (deterministic, associative-safe
    under f32 because XLA fixes the combine order).
    """
    nx, ny, nz = dims
    cell, contrib = pic_kernels.current_contributions(pos, mom, dims)
    flat_cell = cell.reshape(-1)
    flat_contrib = contrib.reshape(-1, 3) * qw
    j = jnp.zeros((nx * ny * nz, 3), dtype=jnp.float32)
    j = j.at[flat_cell].add(flat_contrib)
    return j.T.reshape(3, nx, ny, nz)


def field_update(e, b, j, *, dt):
    """Semi-implicit leapfrog Maxwell update (reference curl — pure jnp:
    stencils fuse well in XLA; no Pallas needed for the mini grids)."""
    return ref.field_update(e, b, j, dt)


def pic_step(e, b, pos, mom, *, qm, qw, dt):
    """One full PIC step. Returns (e', b', pos', mom')."""
    new_pos, new_mom = move_and_mark(e, b, pos, mom, qm=qm, dt=dt)
    j = compute_current(new_pos, new_mom, e.shape[1:], qw=qw)
    e_new, b_new = field_update(e, b, j, dt=dt)
    return e_new, b_new, new_pos, new_mom


# ---------------------------------------------------------------------------
# Stream entry points (PJRT backend of rust/src/babelstream).
# ---------------------------------------------------------------------------

def stream_copy(a):
    return stream_kernels.copy(a)


def stream_mul(c, *, scalar):
    return stream_kernels.mul(c, scalar)


def stream_add(a, b):
    return stream_kernels.add(a, b)


def stream_triad(b, c, *, scalar):
    return stream_kernels.triad(b, c, scalar)


def stream_dot(a, b):
    return stream_kernels.dot(a, b)
