#!/usr/bin/env bash
# CI entry point: lint, tier-1 verification, bench smoke + regression
# gate, and (optionally) one shard of the paper sweep.
#
#   ci/run.sh                      # lint + build + test + fast bench + gate
#   ci/run.sh --full               # benches at full sample counts
#   ci/run.sh --update-baseline    # refresh ci/bench_baseline.json from
#                                  # this machine's bench run (commit it,
#                                  # together with the dated snapshot the
#                                  # gate appends to
#                                  # ci/BENCH_trajectory.json — the perf
#                                  # trajectory tracked across PRs)
#   ci/run.sh --shard i/n          # additionally run shard i of n of the
#                                  # paper sweep (reproduce --all --shard)
#                                  # into out-shard-i-of-n/
#   ci/run.sh --shard i/n --trace-dir D
#                                  # the sweep replays case traces from
#                                  # the persistent archive D (mmap,
#                                  # zero-copy); with
#                                  # ROCLINE_REQUIRE_ARCHIVE_HIT=1 the
#                                  # run FAILS unless zero live
#                                  # recordings happened (the
#                                  # record-once pre-job contract)
#
# CI entry points (see .github/workflows/ci.yml):
#   * record pre-job — `rocline record --out trace-archive
#     --compress=auto` builds the trace archive once with format-v2
#     per-section compression, cached under the cases' content key
#     (`rocline record --print-key`); every shard job restores it and
#     must replay the compressed archive archive-hit only
#     (ROCLINE_REQUIRE_ARCHIVE_HIT=1).
#   * shard matrix — the workflow fans the sweep out as a matrix job
#     over `--shard 0/2` and `--shard 1/2`. Shards deterministically
#     partition the (GPU, case) matrix (coordinator/shard.rs), each
#     case's trace is mmap'd from the shared archive (or recorded once
#     and spilled on a cold cache) and replayed on every GPU, and
#     concatenating the shards' out-shard-*/ directories reproduces the
#     unsharded sweep byte-for-byte.
#   * bench gate — `rocline bench-gate` compares the speedup/* ratios in
#     BENCH_hotpath.json (sharded replay engine vs the sequential
#     reference, plus the phase-isolation ratios: columnar scan vs
#     per-record accessors, routed vs rescan L1, k-way merge vs sort)
#     against the checked-in ci/bench_baseline.json and fails on a
#     >20% regression. Refresh the baseline on a quiet machine with
#     `ci/run.sh --update-baseline` and commit the result together
#     with the dated ci/BENCH_trajectory.json entry it appends.
#     BENCH_hotpath.json itself is uploaded as a per-run artifact by
#     the workflow.
#   * accuracy gate — `rocline reproduce accuracy` runs the six
#     (GPU, case) pairs through the cycle-approximate timing tier and
#     writes the per-GPU worst relative error of the predicted
#     ComputeCurrent time vs the paper's Tables 1 & 2 (both sides
#     geomean-normalized per table) to out-accuracy/accuracy_gate.json.
#     bench-gate merges those acc/* metrics with the hotpath ratios
#     and fails if any error exceeds its ceiling in
#     ci/bench_baseline.json (acc/* gates are ceilings: lower is
#     better). The artifact is uploaded per shard by the workflow.
#   * windowed smoke — `reproduce fig4 --windows 3` (live recording,
#     so the step-windowed parallel record path itself is exercised)
#     must emit byte-identical reports to the default unwindowed
#     pipeline: windowing is a scheduling choice, never an output
#     change.
#   * serve smoke — `rocline serve` is started over the smoke archive
#     (ROCLINE_REQUIRE_ARCHIVE_HIT=1) and must answer per-GPU queries
#     byte-identically to the batch CLI's --format=json output, answer
#     a repeated query from its result cache (asserted via --status
#     counters), and exit cleanly on the in-band shutdown endpoint.
#   * metrics smoke — the same daemon must serve a valid Prometheus
#     page on /v1/metrics (span histograms + counters; obs is
#     default-on for serve) whose serve.requests counter strictly
#     increases between scrapes, and `rocline stats` must render the
#     /v1/metrics.json document.
#   * healthz smoke — the same daemon must answer GET /v1/healthz with
#     200 and state "ok" (the breaker-backed liveness probe described
#     in docs/robustness.md).
#   * chaos smoke — `rocline chaos-soak --seed 42` drives a throwaway
#     daemon through a deterministic, seeded fault schedule
#     (ROCLINE_FAULT injection across archive I/O, codec decode, job
#     panics and socket faults) and fails unless every answer under
#     chaos is byte-identical to the fault-free baseline and the
#     daemon ends healthy (healthz "ok", healed >= quarantined).
#   * streaming smoke — `rocline synth-trace` builds a synthetic
#     archive whose decoded column image dwarfs a hard `ulimit -v`
#     address-space cap; `rocline synth-replay --mode=streaming` must
#     replay it *under* that cap with a counter digest bit-identical
#     to the uncapped resident replay (and the resident tier must
#     FAIL under the same cap, proving the cap binds). This is the
#     out-of-core contract: peak memory bounded by the dispatch
#     working set, not the archive size.
#   * lint — `cargo fmt -- --check` and `cargo clippy -- -D warnings`.
#     Both are skipped with a notice when the component is not
#     installed (offline toolchains); set ROCLINE_LINT_STRICT=1 (the
#     workflow does) to fail the build on lint findings instead of
#     warning.

set -euo pipefail
cd "$(dirname "$0")/.."

SHARD=""
TRACE_DIR=""
FULL=0
UPDATE_BASELINE=0
while [ $# -gt 0 ]; do
    case "$1" in
        --full) FULL=1 ;;
        --update-baseline) UPDATE_BASELINE=1 ;;
        --shard)
            [ $# -ge 2 ] || { echo "--shard needs i/n" >&2; exit 2; }
            SHARD="$2"
            shift
            ;;
        --trace-dir)
            [ $# -ge 2 ] || { echo "--trace-dir needs a path" >&2; exit 2; }
            TRACE_DIR="$2"
            shift
            ;;
        *) echo "unknown argument '$1'" >&2; exit 2 ;;
    esac
    shift
done

lint_failed=0
echo "== lint: cargo fmt -- --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt -- --check || lint_failed=1
else
    echo "rustfmt not installed; skipping"
fi

echo "== lint: cargo clippy -- -D warnings =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings || lint_failed=1
else
    echo "clippy not installed; skipping"
fi

if [ "$lint_failed" = 1 ]; then
    if [ "${ROCLINE_LINT_STRICT:-0}" = 1 ]; then
        echo "lint failed (ROCLINE_LINT_STRICT=1)" >&2
        exit 1
    fi
    echo "WARNING: lint findings above (non-blocking; set" \
         "ROCLINE_LINT_STRICT=1 to enforce)"
fi

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== bench smoke: hotpath =="
if [ "$FULL" = 1 ]; then
    cargo bench --bench hotpath
else
    ROCLINE_BENCH_FAST=1 cargo bench --bench hotpath
fi

test -s BENCH_hotpath.json || {
    echo "BENCH_hotpath.json missing or empty" >&2
    exit 1
}
grep -E '"speedup/' BENCH_hotpath.json || {
    echo "BENCH_hotpath.json has no speedup/* entries (bench names drifted?)" >&2
    exit 1
}

# timing-model accuracy artifact: `reproduce accuracy` compares the
# cycle-approximate predicted ComputeCurrent times against the paper's
# published Tables 1 & 2 (geomean-normalized per table) and writes the
# per-GPU worst rel errs to out-accuracy/accuracy_gate.json as acc/*
# metrics. bench-gate merges that artifact with the hotpath ratios and
# fails if any rel err exceeds its ceiling in ci/bench_baseline.json.
# With --trace-dir the six (GPU, case) runs replay the shared archive
# zero-copy (and ROCLINE_REQUIRE_ARCHIVE_HIT applies as usual).
echo "== accuracy: predicted time vs paper tables -> out-accuracy =="
ACC_CMD=(./target/release/rocline reproduce accuracy --out out-accuracy)
if [ -n "$TRACE_DIR" ]; then
    ACC_CMD+=(--trace-dir "$TRACE_DIR")
fi
"${ACC_CMD[@]}"
test -s out-accuracy/accuracy_gate.json || {
    echo "out-accuracy/accuracy_gate.json missing or empty" >&2
    exit 1
}
grep -E '"acc/predicted_time_rel_err_' out-accuracy/accuracy_gate.json || {
    echo "accuracy_gate.json has no acc/* entries (metric names drifted?)" >&2
    exit 1
}

echo "== bench gate: speedup/* + size/* + acc/* vs ci/bench_baseline.json =="
GATE_BENCH="BENCH_hotpath.json,out-accuracy/accuracy_gate.json"
if [ "$UPDATE_BASELINE" = 1 ]; then
    ./target/release/rocline bench-gate --update-baseline --bench "$GATE_BENCH"
else
    ./target/release/rocline bench-gate --bench "$GATE_BENCH"
fi

# windowed-pipeline smoke: the parallel step-windowed record/replay
# tier (`reproduce --windows N`) must reproduce the default pipeline
# byte-for-byte — every table, CSV, SVG and text report identical.
# Runs live (no --trace-dir) so the windowed *recording* path is the
# thing exercised end to end.
echo "== windowed smoke: reproduce fig4 --windows 3 vs default =="
WIN_DIR="$(mktemp -d "${TMPDIR:-/tmp}/rocline-smoke-win.XXXXXX")"
trap 'rm -rf "$WIN_DIR"' EXIT
ROCLINE_REQUIRE_ARCHIVE_HIT=0 ./target/release/rocline reproduce fig4 \
    --out "$WIN_DIR/plain"
ROCLINE_REQUIRE_ARCHIVE_HIT=0 ./target/release/rocline reproduce fig4 \
    --windows 3 --out "$WIN_DIR/windowed"
diff -r "$WIN_DIR/plain" "$WIN_DIR/windowed" || {
    echo "windowed sweep diverged from the unwindowed pipeline" >&2
    exit 1
}
rm -rf "$WIN_DIR"
trap - EXIT
echo "windowed smoke ok: --windows 3 output byte-identical"

# compressed-archive smoke: a 1-step record with --compress=auto must
# produce a v2 archive that trace-info can summarize (per-section
# encodings + ratios) and that a re-record verifies as an idempotent
# archive hit ("already archived" = the compressed file mmap'd,
# checksum-validated and decoded cleanly). This is the record-once
# pre-job contract in miniature, run on every CI job.
echo "== archive smoke: record --compress=auto round trip =="
SMOKE_ARCH="$(mktemp -d "${TMPDIR:-/tmp}/rocline-smoke-arch.XXXXXX")"
trap 'rm -rf "$SMOKE_ARCH"' EXIT
./target/release/rocline record --out "$SMOKE_ARCH" --steps 1 --compress=auto lwfa
./target/release/rocline trace-info "$SMOKE_ARCH"
./target/release/rocline record --out "$SMOKE_ARCH" --steps 1 --compress=auto lwfa \
    | grep -q "already archived" || {
    echo "compressed archive did not hit on re-record" >&2
    exit 1
}
./target/release/rocline trace-info "$SMOKE_ARCH" --prune lwfa --steps 1

# roofline-as-a-service smoke: start the daemon over the smoke archive
# (ROCLINE_REQUIRE_ARCHIVE_HIT=1 — every query must be answered from
# the mmap'd archive, zero live recordings), prove the per-GPU daemon
# answers are byte-identical to the batch CLI's --format=json output,
# that a repeated query is a cache hit (service counters over
# --status), and that in-band shutdown exits the daemon cleanly.
echo "== serve smoke: daemon vs batch byte-identity =="
SERVE_LOG="$SMOKE_ARCH/serve.log"
ROCLINE_REQUIRE_ARCHIVE_HIT=1 ./target/release/rocline serve \
    --addr 127.0.0.1:0 --trace-dir "$SMOKE_ARCH" >"$SERVE_LOG" 2>&1 &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true; rm -rf "$SMOKE_ARCH"' EXIT
SERVE_URL=""
for _ in $(seq 1 100); do
    SERVE_URL="$(sed -n 's|^rocline serve listening on \(http://.*\)$|\1|p' "$SERVE_LOG")"
    [ -n "$SERVE_URL" ] && break
    kill -0 "$SERVE_PID" 2>/dev/null || {
        echo "serve daemon died during startup:" >&2
        cat "$SERVE_LOG" >&2
        exit 1
    }
    sleep 0.1
done
[ -n "$SERVE_URL" ] || {
    echo "serve daemon never announced its address:" >&2
    cat "$SERVE_LOG" >&2
    exit 1
}
for GPU in v100 mi60 mi100; do
    ./target/release/rocline query --gpu "$GPU" --case lwfa --steps 1 \
        --format=json --trace-dir "$SMOKE_ARCH" >"$SMOKE_ARCH/batch-$GPU.json"
    ./target/release/rocline query --gpu "$GPU" --case lwfa --steps 1 \
        --url "$SERVE_URL" >"$SMOKE_ARCH/served-$GPU.json"
    cmp "$SMOKE_ARCH/batch-$GPU.json" "$SMOKE_ARCH/served-$GPU.json" || {
        echo "daemon answer for $GPU differs from the batch CLI" >&2
        exit 1
    }
done
# warm re-query, then read the service counters: cache_hits must have
# moved and recordings must still be zero (the archive-hit contract,
# daemon edition)
./target/release/rocline query --gpu mi100 --case lwfa --steps 1 \
    --url "$SERVE_URL" >/dev/null
STATUS_JSON="$(./target/release/rocline query --url "$SERVE_URL" --status)"
echo "serve status: $STATUS_JSON"
case "$STATUS_JSON" in
    *'"recordings":0'*) ;;
    *) echo "daemon recorded live despite the archive" >&2; exit 1 ;;
esac
case "$STATUS_JSON" in
    *'"cache_hits":0'*) echo "warm re-query was not a cache hit" >&2; exit 1 ;;
    *'"cache_hits":'*) ;;
    *) echo "no cache_hits counter in: $STATUS_JSON" >&2; exit 1 ;;
esac
# self-profiling smoke: the daemon (obs default-on) must expose a
# valid Prometheus page on /v1/metrics with span histograms from the
# queries above, and the serve.requests counter must strictly
# increase between two scrapes (each scrape is itself a request).
# Raw HTTP over bash's /dev/tcp — no curl dependency in CI.
echo "== metrics smoke: /v1/metrics Prometheus exposition =="
scrape_metrics() {
    local hostport="${SERVE_URL#http://}"
    exec 9<>"/dev/tcp/${hostport%%:*}/${hostport##*:}"
    printf 'GET /v1/metrics HTTP/1.1\r\nHost: %s\r\nConnection: close\r\n\r\n' \
        "$hostport" >&9
    cat <&9
    exec 9<&- 9>&-
}
SCRAPE1="$(scrape_metrics)"
echo "$SCRAPE1" | grep -q '^rocline_uptime_seconds ' || {
    echo "/v1/metrics page has no uptime gauge:" >&2
    echo "$SCRAPE1" >&2
    exit 1
}
echo "$SCRAPE1" | grep -q 'rocline_span_duration_seconds_bucket{span="serve.request"' || {
    echo "/v1/metrics page has no serve.request span histogram" >&2
    exit 1
}
REQ1="$(echo "$SCRAPE1" | sed -n 's/^rocline_serve_requests_total \([0-9]*\)$/\1/p')"
SCRAPE2="$(scrape_metrics)"
REQ2="$(echo "$SCRAPE2" | sed -n 's/^rocline_serve_requests_total \([0-9]*\)$/\1/p')"
[ -n "$REQ1" ] && [ -n "$REQ2" ] && [ "$REQ2" -gt "$REQ1" ] || {
    echo "serve.requests did not increase between scrapes ('$REQ1' -> '$REQ2')" >&2
    exit 1
}
# the stats CLI view over the same registry (/v1/metrics.json)
./target/release/rocline stats --url "$SERVE_URL" | grep -q "observability on" || {
    echo "rocline stats did not render the daemon's registry" >&2
    exit 1
}
echo "metrics smoke ok: Prometheus page valid, serve.requests $REQ1 -> $REQ2"
# liveness probe: after a clean query run the breaker must be closed,
# so /v1/healthz answers 200 with state "ok"
echo "== healthz smoke: GET /v1/healthz =="
scrape_healthz() {
    local hostport="${SERVE_URL#http://}"
    exec 9<>"/dev/tcp/${hostport%%:*}/${hostport##*:}"
    printf 'GET /v1/healthz HTTP/1.1\r\nHost: %s\r\nConnection: close\r\n\r\n' \
        "$hostport" >&9
    cat <&9
    exec 9<&- 9>&-
}
HEALTHZ="$(scrape_healthz)"
echo "$HEALTHZ" | head -n 1 | grep -q '200' || {
    echo "/v1/healthz did not answer 200:" >&2
    echo "$HEALTHZ" >&2
    exit 1
}
echo "$HEALTHZ" | grep -q '"state":"ok"' || {
    echo "/v1/healthz state is not ok:" >&2
    echo "$HEALTHZ" >&2
    exit 1
}
echo "healthz smoke ok: state ok on a healthy daemon"
./target/release/rocline query --url "$SERVE_URL" --shutdown >/dev/null
wait "$SERVE_PID" || {
    echo "serve daemon exited uncleanly after /v1/shutdown" >&2
    exit 1
}
trap 'rm -rf "$SMOKE_ARCH"' EXIT
echo "serve smoke ok: byte-identical answers, cache hit, clean shutdown"

# bounded-memory streaming smoke: build a synth archive whose decoded
# column image (~700 MiB: stride workload, 2^21 threads x 20
# dispatches at ~17 decoded bytes/thread) dwarfs a hard 512 MiB
# address-space cap, then prove the out-of-core tier replays it
# bit-identically while staying under the cap. Three legs:
#   1. resident replay, uncapped       -> reference counter digest
#   2. resident replay under the cap   -> must FAIL (the cap binds:
#      the mapped tier has to hold the whole decoded arena)
#   3. streaming replay under the cap  -> must SUCCEED with the same
#      digest (decode-ahead holds only ~2 dispatch arenas)
# The cap leaves headroom for the worker pool's reserved thread
# stacks (up to 16 x 8 MiB of address space), which ulimit -v counts.
echo "== streaming smoke: out-of-core replay under a 512 MiB ulimit -v =="
SMOKE_SYNTH="$(mktemp -d "${TMPDIR:-/tmp}/rocline-smoke-synth.XXXXXX")"
trap 'rm -rf "$SMOKE_ARCH" "$SMOKE_SYNTH"' EXIT
SYNTH_RTRC="$(./target/release/rocline synth-trace --out "$SMOKE_SYNTH" \
    --case stride --n 2097152 --dispatches 20 --seed 7 --compress=force)"
RES_LINE="$(./target/release/rocline synth-replay "$SYNTH_RTRC" --mode=resident)"
echo "resident  (uncapped): $RES_LINE"
STREAM_CAP_KB=$((512 * 1024))
if (ulimit -v "$STREAM_CAP_KB"; exec ./target/release/rocline \
        synth-replay "$SYNTH_RTRC" --mode=resident) >/dev/null 2>&1; then
    echo "resident replay fit under the cap — smoke archive too small" \
         "to prove anything; grow --n/--dispatches" >&2
    exit 1
fi
STREAM_LINE="$( (ulimit -v "$STREAM_CAP_KB"; exec ./target/release/rocline \
    synth-replay "$SYNTH_RTRC" --mode=streaming) )"
echo "streaming (capped):   $STREAM_LINE"
RES_DIGEST="${RES_LINE%% *}"
STREAM_DIGEST="${STREAM_LINE%% *}"
case "$RES_DIGEST" in
    digest=*) ;;
    *) echo "unexpected synth-replay output: '$RES_LINE'" >&2; exit 1 ;;
esac
[ "$RES_DIGEST" = "$STREAM_DIGEST" ] || {
    echo "streaming replay diverged from resident:" >&2
    echo "  resident:  $RES_LINE" >&2
    echo "  streaming: $STREAM_LINE" >&2
    exit 1
}
echo "streaming smoke ok: bit-identical under the cap ($RES_DIGEST)"

# chaos smoke: seeded fault injection against a live daemon. The soak
# runs its own throwaway daemon + archive (phase 1 fault-free baseline,
# phase 2 chaos with ROCLINE_FAULT-style injection, phase 3 recovery)
# and fails in-process unless every chaos-phase answer is byte-identical
# to the baseline and the daemon ends healthy. Deterministic: same seed
# -> same fault schedule -> same transcript.
echo "== chaos smoke: rocline chaos-soak --seed 42 =="
# the soak records its own throwaway cases live, so the record-once
# contract variable (exported job-wide by the shard matrix) must not
# apply to it
CHAOS_LINE="$(ROCLINE_REQUIRE_ARCHIVE_HIT=0 \
    ./target/release/rocline chaos-soak --seed 42 --queries 12)"
echo "$CHAOS_LINE"
case "$CHAOS_LINE" in
    *"chaos soak ok"*) ;;
    *) echo "chaos soak did not report success" >&2; exit 1 ;;
esac

if [ -n "$SHARD" ]; then
    OUT="out-shard-${SHARD//\//-of-}"
    echo "== paper sweep shard $SHARD -> $OUT =="
    CMD=(./target/release/rocline reproduce --all --shard "$SHARD" --out "$OUT")
    if [ -n "$TRACE_DIR" ]; then
        CMD+=(--trace-dir "$TRACE_DIR")
    fi
    # with ROCLINE_REQUIRE_ARCHIVE_HIT=1 in the environment, rocline
    # itself fails the sweep (fail-closed, in-process) if any case
    # trace was recorded live despite --trace-dir — no log scraping
    "${CMD[@]}"
    if [ -n "$TRACE_DIR" ] && [ "${ROCLINE_REQUIRE_ARCHIVE_HIT:-0}" = 1 ]; then
        echo "archive-hit contract ok: zero live recordings"
        if [ -d "$TRACE_DIR" ]; then
            ./target/release/rocline trace-info "$TRACE_DIR"
        fi
    fi
fi

echo "== ok =="
