#!/usr/bin/env bash
# Tier-1 verification plus a bench smoke pass.
#
#   ci/run.sh          # build + test + fast bench, checks the artifact
#   ci/run.sh --full   # same but benches at full sample counts
#
# The bench step runs `benches/hotpath.rs`, which writes
# BENCH_hotpath.json (bench name -> ops/s, plus speedup/* ratios of the
# sharded replay engine over the sequential baseline) at the repo root.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== bench smoke: hotpath =="
if [ "${1:-}" = "--full" ]; then
    cargo bench --bench hotpath
else
    ROCLINE_BENCH_FAST=1 cargo bench --bench hotpath
fi

test -s BENCH_hotpath.json || {
    echo "BENCH_hotpath.json missing or empty" >&2
    exit 1
}
grep -E '"speedup/' BENCH_hotpath.json || {
    echo "BENCH_hotpath.json has no speedup/* entries (bench names drifted?)" >&2
    exit 1
}
echo "== ok: BENCH_hotpath.json =="
